#!/usr/bin/env python3
"""Development mirror of the Rust `illm-lint` analyzer (rust/src/lint/).

The authoring sandbox for this repo has no Rust toolchain, so the lint's
tokenizer and rule logic are maintained twice: the shipping implementation
in rust/src/lint/ (what CI runs via `make lint`) and this 1:1 Python port,
which lets the rules be exercised against the tree without cargo. Keep the
two in sync — rule semantics are documented in rust/src/lint/mod.rs.

Usage: python3 python/lint_sim.py [--src rust/src] [--allow rust/lint_allow.toml]
Exit code 1 if violations remain.
"""

import os
import re
import sys

# ---------------------------------------------------------------- tokenizer

IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
IDENT_CONT = IDENT_START | set("0123456789")
DIGITS = set("0123456789")

PUNCTS3 = ["<<=", ">>=", "..="]
PUNCTS2 = ["->", "=>", "::", "<<", ">>", "<=", ">=", "==", "!=", "&&",
           "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", ".."]

IDENT, INT, FLOAT, STR, CHAR, PUNCT, LIFETIME = range(7)


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind
        self.text = text
        self.line = line

    def __repr__(self):
        return f"{self.text}@{self.line}"


def tokenize(src):
    """-> (tokens, directives {line: [text]}).

    Strings/chars become placeholder tokens; comments are stripped, but
    `// ovf: ...` and `// lint: ...` comments are recorded as directives
    keyed by their line."""
    toks = []
    directives = {}
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            if j < 0:
                j = n
            body = src[i + 2:j].lstrip("/!").strip()
            if body.startswith("ovf:") or body.startswith("lint:"):
                directives.setdefault(line, []).append(body)
            i = j
            continue
        if src.startswith("/*", i):
            depth, i = 1, i + 2
            while i < n and depth > 0:
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        # raw strings r"..", r#".."#, br#".."#
        m = re.match(r'(b?r)(#*)"', src[i:])
        if m:
            hashes = m.group(2)
            close = '"' + hashes
            j = src.find(close, i + len(m.group(0)))
            if j < 0:
                j = n
            line += src.count("\n", i, j)
            toks.append(Tok(STR, "", line))
            i = j + len(close)
            continue
        if c == '"' or src.startswith('b"', i):
            i += 2 if c == "b" else 1
            while i < n:
                if src[i] == "\\":
                    i += 2
                    continue
                if src[i] == '"':
                    i += 1
                    break
                if src[i] == "\n":
                    line += 1
                i += 1
            toks.append(Tok(STR, "", line))
            continue
        # char / byte-char / lifetime
        if c == "'" or src.startswith("b'", i):
            start = i + (2 if c == "b" else 1)
            if c == "'" and start < n and src[start] in IDENT_START \
                    and not (start + 1 < n and src[start + 1] == "'"):
                # lifetime 'a — also covers 'static
                j = start
                while j < n and src[j] in IDENT_CONT:
                    j += 1
                toks.append(Tok(LIFETIME, src[i:j], line))
                i = j
                continue
            i = start
            while i < n:
                if src[i] == "\\":
                    i += 2
                    continue
                if src[i] == "'":
                    i += 1
                    break
                i += 1
            toks.append(Tok(CHAR, "", line))
            continue
        if c in IDENT_START:
            j = i
            while j < n and src[j] in IDENT_CONT:
                j += 1
            toks.append(Tok(IDENT, src[i:j], line))
            i = j
            continue
        if c in DIGITS:
            j = i
            is_float = False
            if src.startswith(("0x", "0o", "0b"), i):
                j = i + 2
                while j < n and (src[j] in IDENT_CONT):
                    j += 1
            else:
                while j < n and (src[j] in DIGITS or src[j] == "_"):
                    j += 1
                if j < n and src[j] == "." and j + 1 < n \
                        and src[j + 1] in DIGITS:
                    is_float = True
                    j += 1
                    while j < n and (src[j] in DIGITS or src[j] == "_"):
                        j += 1
                if j < n and src[j] in "eE" and (
                        j + 1 < n and (src[j + 1] in DIGITS
                                       or src[j + 1] in "+-")):
                    is_float = True
                    j += 1
                    if src[j] in "+-":
                        j += 1
                    while j < n and src[j] in DIGITS:
                        j += 1
                # suffix
                k = j
                while k < n and src[k] in IDENT_CONT:
                    k += 1
                suffix = src[j:k]
                if suffix in ("f32", "f64"):
                    is_float = True
                j = k
            toks.append(Tok(FLOAT if is_float else INT, src[i:j], line))
            i = j
            continue
        matched = None
        for p in PUNCTS3:
            if src.startswith(p, i):
                matched = p
                break
        if not matched:
            for p in PUNCTS2:
                if src.startswith(p, i):
                    matched = p
                    break
        if not matched:
            matched = c
        toks.append(Tok(PUNCT, matched, line))
        i += len(matched)
    return toks, directives


# ------------------------------------------------------------ file modeling

class FnInfo:
    def __init__(self, qname, name, path, body, is_test, sig_line):
        self.qname = qname      # "Type::name" or "name"
        self.name = name
        self.path = path
        self.body = body        # token slice of the body (inside braces)
        self.is_test = is_test
        self.sig_line = sig_line
        self.direct_locks = set()
        self.calls = []         # (name, qual_or_None, held tuple, line, pin)
        self.may_locks = set()
        self.is_compute = False
        self.may_compute = False


def mark_test_regions(toks):
    """Per-token bool: inside an item annotated #[cfg(test)] (or inside
    #[test] / #[bench] attributes' items)."""
    in_test = [False] * len(toks)
    i = 0
    regions = []  # stack of close-depth
    depth = 0
    pending = False
    while i < len(toks):
        t = toks[i]
        if t.kind == PUNCT and t.text == "#" and i + 1 < len(toks) \
                and toks[i + 1].text == "[":
            # scan attribute
            j = i + 2
            bd = 1
            attr = []
            while j < len(toks) and bd > 0:
                if toks[j].text == "[":
                    bd += 1
                elif toks[j].text == "]":
                    bd -= 1
                else:
                    attr.append(toks[j].text)
                j += 1
            if ("cfg" in attr and "test" in attr) or attr[:1] == ["test"] \
                    or attr[:1] == ["bench"]:
                pending = True
            for k in range(i, j):
                if regions:
                    in_test[k] = True
            i = j
            continue
        if t.kind == PUNCT and t.text == "{":
            depth += 1
            if pending:
                regions.append(depth)
                pending = False
        elif t.kind == PUNCT and t.text == "}":
            if regions and regions[-1] == depth:
                regions.pop()
            depth -= 1
        elif t.kind == PUNCT and t.text == ";" and pending and depth == 0:
            pending = False  # e.g. `#[cfg(test)] mod tests;`
        if regions:
            in_test[i] = True
        i += 1
    return in_test


KEYWORDS = {"if", "while", "for", "match", "return", "loop", "fn", "let",
            "mut", "ref", "move", "in", "as", "pub", "crate", "self",
            "Self", "use", "mod", "impl", "where", "unsafe", "else",
            "break", "continue", "struct", "enum", "trait", "const",
            "static", "type", "dyn", "box"}


def parse_fns(path, toks, in_test):
    """Extract fn items with impl-type qualification."""
    fns = []
    i = 0
    impl_stack = []  # (type_name, close_depth)
    depth = 0
    while i < len(toks):
        t = toks[i]
        if t.kind == PUNCT and t.text == "{":
            depth += 1
        elif t.kind == PUNCT and t.text == "}":
            while impl_stack and impl_stack[-1][1] == depth:
                impl_stack.pop()
            depth -= 1
        elif t.kind == IDENT and t.text == "impl":
            # scan to the opening '{' (or ';'), find the type name
            j = i + 1
            names = []
            gd = 0
            last_for = -1
            while j < len(toks):
                tj = toks[j]
                if tj.text == "<":
                    gd += 1
                elif tj.text == ">":
                    gd = max(0, gd - 1)
                elif tj.text == "{" and gd == 0:
                    break
                elif tj.text == ";" and gd == 0:
                    break
                elif tj.kind == IDENT and gd == 0:
                    if tj.text == "for":
                        last_for = len(names)
                    elif tj.text not in ("where", "dyn"):
                        names.append(tj.text)
                j += 1
            tyname = None
            if last_for >= 0 and last_for < len(names):
                tyname = names[last_for]
            elif names:
                tyname = names[-1]
            if j < len(toks) and toks[j].text == "{":
                impl_stack.append((tyname, depth + 1))
                depth += 1
                i = j + 1
                continue
        elif t.kind == IDENT and t.text == "fn" and i + 1 < len(toks) \
                and toks[i + 1].kind == IDENT:
            name = toks[i + 1].text
            sig_line = t.line
            # find body '{' at this depth (skip generics/args/ret/where)
            j = i + 2
            gd = 0
            pd = 0
            body = None
            while j < len(toks):
                tj = toks[j]
                if tj.text == "<":
                    gd += 1
                elif tj.text == ">" and gd > 0:
                    gd -= 1
                elif tj.text in ("(", "["):
                    pd += 1
                elif tj.text in (")", "]"):
                    pd -= 1
                elif tj.text == ";" and pd == 0 and gd == 0:
                    break  # trait method decl, no body
                elif tj.text == "{" and pd == 0:
                    # body span
                    bd = 1
                    k = j + 1
                    while k < len(toks) and bd > 0:
                        if toks[k].text == "{":
                            bd += 1
                        elif toks[k].text == "}":
                            bd -= 1
                        k += 1
                    body = toks[j + 1:k - 1]
                    break
                j += 1
            ty = impl_stack[-1][0] if impl_stack else None
            qname = f"{ty}::{name}" if ty else name
            fns.append(FnInfo(qname, name, path, body or [],
                              in_test[i], sig_line))
            # fall through WITHOUT skipping: the body's braces must pass
            # through the depth tracker so impl blocks close correctly
        i += 1
    return fns


# ------------------------------------------------------------------- rules

TRIE, POOL, LEAF = 0, 1, 2
LOCK_NAMES = {TRIE: "prefix-trie", POOL: "kv-pool", LEAF: "leaf"}

COMPUTE = {"broadcast", "gemm_span", "attend_head", "attend_row",
           "merge_heads", "di_softmax_row", "di_softmax_rows",
           "di_exp_row", "di_norm", "di_add", "di_swiglu", "di_relu",
           "di_linear_raw", "di_linear_raw_threads", "di_linear",
           "di_linear_threads", "attention", "forward_raw",
           "layer_tail", "layer_tail_threads"}

# Method names that collide with std (Vec/slice/HashMap/Iterator/...).
# An unpinned `.name(` call with one of these names is NOT union-resolved
# against same-named crate fns — the overwhelming majority of such calls
# are std methods and union resolution would wire unrelated code together.
# A `// lint: callee=Type::fn` pin on the call line restores exact
# resolution for the rare crate method that shadows a std name.
STD_METHODS = {"get", "get_mut", "insert", "remove", "push", "pop",
               "append", "collect", "extend", "clone", "min", "max",
               "last", "first", "len", "is_empty", "contains", "iter",
               "map", "take", "wait", "drain", "retain", "entry",
               "split_off", "get_or_init", "find", "sum", "fold",
               "next", "rev", "count", "sort", "clear", "join"}

FLOAT_ROOTS = {"prefill_raw", "decode_raw", "decode_batch_raw"}
REACH_DIRS = ("ops/", "int_model/", "tensor/", "quant/")
SERVING_DIRS = ("ops/", "int_model/", "coordinator/", "trace/", "util/",
                "quant/", "tensor/")
# file prefixes skipped by every rule (the analyzer itself + binaries)
SKIP_PREFIX = ("lint/", "bin/", "main.rs")


def classify_lock_arg(args):
    if "prefix" in args:
        return TRIE
    if "decode_scratch" in args or "state" in args or "events" in args:
        return LEAF
    return None


class Violation:
    def __init__(self, rule, path, line, item, msg):
        self.rule = rule
        self.path = path
        self.line = line
        self.item = item
        self.msg = msg

    def __repr__(self):
        return f"[{self.rule}] {self.path}:{self.line} ({self.item}) {self.msg}"


def analyze_fn_events(fn, registry_names):
    """Populate fn.direct_locks and fn.calls with held-lock context."""
    toks = fn.body
    held_guards = {}   # name -> (lock, scope_depth)
    held_temps = []    # locks held to end of statement
    scope = 0
    i = 0
    pins = {}          # line -> {fnname: qname}
    for line, ds in fn.directives.items() if False else []:
        pass

    def held_now():
        locks = [l for (l, _) in held_guards.values()] + held_temps
        return tuple(sorted(set(locks)))

    while i < len(toks):
        t = toks[i]
        if t.kind == PUNCT and t.text in ("{", "}", ";"):
            if t.text == "{":
                scope += 1
            elif t.text == "}":
                dead = [g for g, (_, d) in held_guards.items() if d == scope]
                for g in dead:
                    del held_guards[g]
                scope -= 1
            held_temps = []
            i += 1
            continue
        if t.kind == IDENT and t.text in ("lock_pool", "lock_recover") \
                and i + 1 < len(toks) and toks[i + 1].text == "(":
            # arg scan to matching ')'
            j = i + 2
            pd = 1
            args = []
            while j < len(toks) and pd > 0:
                if toks[j].text == "(":
                    pd += 1
                elif toks[j].text == ")":
                    pd -= 1
                elif toks[j].kind == IDENT:
                    args.append(toks[j].text)
                j += 1
            if t.text == "lock_pool":
                lock = POOL
            else:
                lock = classify_lock_arg(args)
            if lock is None:
                fn.unknown_locks.append(t.line)
                i = j
                continue
            # ordering at acquisition
            cur = held_now()
            if cur and lock <= max(cur):
                fn.order_viols.append(
                    (t.line, f"acquires {LOCK_NAMES[lock]} while "
                             f"{[LOCK_NAMES[c] for c in cur]} held"))
            # binding or temp?
            bound = None
            if i >= 2 and toks[i - 1].text == "=" and \
                    toks[i - 2].kind == IDENT:
                name = toks[i - 2].text
                k = i - 3
                if k >= 0 and toks[k].text == "mut":
                    k -= 1
                if k >= 0 and toks[k].text == "let" \
                        and j < len(toks) and toks[j].text == ";":
                    bound = name
            if bound:
                held_guards[bound] = (lock, scope)
            else:
                held_temps.append(lock)
            i = j
            continue
        # drop(guard)
        if t.kind == IDENT and t.text == "drop" and i + 2 < len(toks) \
                and toks[i + 1].text == "(" \
                and toks[i + 2].kind == IDENT \
                and toks[i + 2].text in held_guards:
            del held_guards[toks[i + 2].text]
            i += 3
            continue
        # call site
        if t.kind == IDENT and t.text not in KEYWORDS \
                and i + 1 < len(toks) and toks[i + 1].text == "(":
            name = t.text
            if name in ("drop",):
                i += 1
                continue
            qual = None
            if i >= 2 and toks[i - 1].text == "::" \
                    and toks[i - 2].kind == IDENT:
                qual = toks[i - 2].text
            is_method = i >= 1 and toks[i - 1].text == "."
            if name in registry_names or (qual and
                                          f"{qual}::{name}" in
                                          registry_names):
                pin = None
                for dline in (t.line,):
                    for d in fn.file_directives.get(dline, []):
                        m = re.match(r"lint:\s*callee\s*=\s*(\w+)::(\w+)",
                                     d)
                        if m and m.group(2) == name:
                            pin = f"{m.group(1)}::{m.group(2)}"
                fn.calls.append((name, qual, held_now(), t.line, pin,
                                 is_method))
            i += 1
            continue
        i += 1
    fn.direct_locks = set()
    # re-derive direct locks (any acquisition at all)
    for i, t in enumerate(toks):
        if t.kind == IDENT and t.text == "lock_pool" \
                and i + 1 < len(toks) and toks[i + 1].text == "(":
            fn.direct_locks.add(POOL)
        if t.kind == IDENT and t.text == "lock_recover" \
                and i + 1 < len(toks) and toks[i + 1].text == "(":
            j = i + 2
            pd = 1
            args = []
            while j < len(toks) and pd > 0:
                if toks[j].text == "(":
                    pd += 1
                elif toks[j].text == ")":
                    pd -= 1
                elif toks[j].kind == IDENT:
                    args.append(toks[j].text)
                j += 1
            lock = classify_lock_arg(args)
            if lock is not None:
                fn.direct_locks.add(lock)


def load_allow(path):
    entries = []
    if not os.path.exists(path):
        return entries, []
    cur = None
    errs = []
    with open(path) as f:
        for ln, raw in enumerate(f, 1):
            s = raw.strip()
            if not s or s.startswith("#"):
                continue
            if s == "[[allow]]":
                if cur is not None:
                    entries.append(cur)
                cur = {}
                continue
            m = re.match(r'(\w+)\s*=\s*"(.*)"\s*$', s)
            if m and cur is not None:
                cur[m.group(1)] = m.group(2)
            else:
                errs.append(f"lint_allow.toml:{ln}: unparsable line: {s}")
    if cur is not None:
        entries.append(cur)
    for e in entries:
        if not e.get("reason", "").strip():
            errs.append(f"allow entry {e} missing justification (reason)")
        if "rule" not in e or "file" not in e:
            errs.append(f"allow entry {e} missing rule/file")
    return entries, errs


def allowed(entries, rule, path, item, text=""):
    for e in entries:
        if e.get("rule") != rule:
            continue
        if e.get("file") != path:
            continue
        it = e.get("item")
        if it and it not in (item, item.split("::")[-1]):
            continue
        pat = e.get("pattern")
        if pat and pat not in text:
            continue
        e["_used"] = True
        return True
    return False


def main():
    src_root = "rust/src"
    allow_path = "rust/lint_allow.toml"
    args = sys.argv[1:]
    while args:
        a = args.pop(0)
        if a == "--src":
            src_root = args.pop(0)
        elif a == "--allow":
            allow_path = args.pop(0)
    files = []
    for dirpath, _, names in os.walk(src_root):
        for nm in sorted(names):
            if nm.endswith(".rs"):
                full = os.path.join(dirpath, nm)
                rel = os.path.relpath(full, src_root).replace(os.sep, "/")
                files.append((rel, full))
    files.sort()

    allow, allow_errs = load_allow(allow_path)
    viols = [Violation("allowlist", allow_path, 0, "-", e)
             for e in allow_errs]

    registry = {}          # qname -> FnInfo
    by_name = {}           # name -> [FnInfo]
    file_toks = {}
    file_dirs = {}
    file_tests = {}

    for rel, full in files:
        if rel.startswith(SKIP_PREFIX):
            continue
        with open(full) as f:
            src = f.read()
        toks, directives = tokenize(src)
        in_test = mark_test_regions(toks)
        file_toks[rel] = toks
        file_dirs[rel] = directives
        file_tests[rel] = in_test
        for fn in parse_fns(rel, toks, in_test):
            fn.file_directives = directives
            fn.unknown_locks = []
            fn.order_viols = []
            if fn.is_test:
                continue
            if fn.name in ("lock_pool", "lock_recover"):
                continue  # the locking primitives themselves
            registry[f"{rel}::{fn.qname}"] = fn
            by_name.setdefault(fn.name, []).append(fn)
            by_name.setdefault(fn.qname, [])
            if fn.qname not in by_name or fn not in by_name[fn.qname]:
                by_name.setdefault(fn.qname, []).append(fn)

    names_set = set(by_name.keys())
    for fn in registry.values():
        analyze_fn_events(fn, names_set)

    # map (file, line) -> fn qname for messages
    fn_spans = {}
    for fn in registry.values():
        if fn.body:
            fn_spans.setdefault(fn.path, []).append(
                (fn.body[0].line, fn.body[-1].line, fn.qname))

    def owner_fn(rel, line):
        for lo, hi, q in fn_spans.get(rel, []):
            if lo <= line <= hi:
                return q
        return "-"

    def resolve(call):
        name, qual, _held, _line, pin, is_method = call
        if pin and pin in by_name:
            return by_name[pin]
        if qual:
            q = f"{qual}::{name}"
            if q in by_name and by_name[q]:
                return by_name[q]
            return []  # qualified path to a non-crate fn
        if is_method and name in STD_METHODS:
            return []  # std-shadowed name, unpinned: out of scope
        return by_name.get(name, [])

    # transitive fixed point: may_locks / may_compute
    for fn in registry.values():
        fn.may_locks = set(fn.direct_locks)
        fn.is_compute = fn.name in COMPUTE
        fn.may_compute = fn.is_compute
    changed = True
    while changed:
        changed = False
        for fn in registry.values():
            for call in fn.calls:
                for callee in resolve(call):
                    if not callee.may_locks <= fn.may_locks:
                        fn.may_locks |= callee.may_locks
                        changed = True
                    if callee.may_compute and not fn.may_compute:
                        fn.may_compute = True
                        changed = True

    # ---- rule 2: lock order + compute-under-lock ----
    for fn in registry.values():
        for line in fn.unknown_locks:
            viols.append(Violation(
                "lock-order", fn.path, line, fn.qname,
                "lock_recover on an unregistered mutex — classify it in "
                "the lint lock table"))
        for line, msg in fn.order_viols:
            if not allowed(allow, "lock-order", fn.path, fn.qname):
                viols.append(Violation("lock-order", fn.path, line,
                                       fn.qname, msg))
        for call in fn.calls:
            name, qual, held, line, pin, is_method = call
            if not held:
                continue
            callees = resolve(call)
            bad_locks = set()
            compute = None
            for c in callees:
                bad_locks |= {l for l in c.may_locks if l <= max(held)}
                if c.may_compute:
                    compute = c.qname
            if bad_locks and not allowed(allow, "lock-order", fn.path,
                                         fn.qname, name):
                viols.append(Violation(
                    "lock-order", fn.path, line, fn.qname,
                    f"call {name}() may acquire "
                    f"{[LOCK_NAMES[l] for l in sorted(bad_locks)]} while "
                    f"{[LOCK_NAMES[h] for h in held]} held"))
            if compute and not allowed(allow, "lock-order", fn.path,
                                       fn.qname, name):
                viols.append(Violation(
                    "lock-order", fn.path, line, fn.qname,
                    f"compute call {name}() (via {compute}) while "
                    f"{[LOCK_NAMES[h] for h in held]} held"))

    # ---- rule 1: float freedom ----
    def check_floats(fn, why):
        found = []
        for t in fn.body:
            if t.kind == FLOAT:
                found.append((t.line, f"float literal {t.text}"))
            elif t.kind == IDENT and t.text in ("f32", "f64"):
                found.append((t.line, f"{t.text} token"))
        for line, what in found:
            if not allowed(allow, "float-freedom", fn.path, fn.qname):
                viols.append(Violation("float-freedom", fn.path, line,
                                       fn.qname, f"{what} ({why})"))

    float_files = [rel for rel in file_toks
                   if re.match(r"ops/(di_\w+|rope|mod)\.rs$", rel)]
    seen_float = set()
    for fn in registry.values():
        if fn.path in float_files:
            check_floats(fn, "DI-kernel file scope")
            seen_float.add(id(fn))
    # reachability from the raw serving paths
    reach = set()
    work = [f for f in registry.values() if f.name in FLOAT_ROOTS]
    while work:
        fn = work.pop()
        if id(fn) in reach:
            continue
        reach.add(id(fn))
        for call in fn.calls:
            for callee in resolve(call):
                if callee.path.startswith(REACH_DIRS):
                    work.append(callee)
    for fn in registry.values():
        if id(fn) in reach and id(fn) not in seen_float:
            check_floats(fn, "reachable from prefill_raw/decode_raw/"
                             "decode_batch_raw")

    # ---- rule 3: atomics + panic discipline ----
    for rel, toks in file_toks.items():
        in_test = file_tests[rel]
        if not rel.startswith(SERVING_DIRS):
            continue
        for i, t in enumerate(toks):
            if in_test[i]:
                continue
            if t.kind == IDENT and t.text == "Relaxed" and i >= 2 \
                    and toks[i - 1].text == "::" \
                    and toks[i - 2].text == "Ordering":
                if not rel.startswith("trace/") and \
                        not allowed(allow, "atomics", rel, "-"):
                    viols.append(Violation(
                        "atomics", rel, t.line, "-",
                        "Ordering::Relaxed outside trace/"))
            if t.kind == IDENT and t.text == "unwrap" \
                    and i + 2 < len(toks) and toks[i + 1].text == "(" \
                    and toks[i + 2].text == ")" \
                    and i >= 1 and toks[i - 1].text == ".":
                if not allowed(allow, "panic-discipline", rel,
                               owner_fn(rel, t.line), "unwrap"):
                    viols.append(Violation(
                        "panic-discipline", rel, t.line,
                        owner_fn(rel, t.line),
                        "unwrap() on the serving path"))
            if t.kind == IDENT and t.text == "expect" \
                    and i + 2 < len(toks) and toks[i + 1].text == "(" \
                    and toks[i + 2].kind == STR \
                    and i >= 1 and toks[i - 1].text == ".":
                if not allowed(allow, "panic-discipline", rel,
                               owner_fn(rel, t.line), "expect"):
                    viols.append(Violation(
                        "panic-discipline", rel, t.line,
                        owner_fn(rel, t.line),
                        "expect() on the serving path"))
            if t.kind == IDENT and t.text in ("panic", "unreachable",
                                              "todo", "unimplemented") \
                    and i + 1 < len(toks) and toks[i + 1].text == "!":
                if not allowed(allow, "panic-discipline", rel,
                               owner_fn(rel, t.line), t.text):
                    viols.append(Violation(
                        "panic-discipline", rel, t.line,
                        owner_fn(rel, t.line),
                        f"{t.text}! on the serving path"))
            if t.kind == IDENT and t.text == "lock" \
                    and i >= 1 and toks[i - 1].text == "." \
                    and i + 2 < len(toks) and toks[i + 1].text == "(" \
                    and toks[i + 2].text == ")" \
                    and rel != "util/mod.rs":
                if not allowed(allow, "lock-order", rel,
                               owner_fn(rel, t.line), "lock"):
                    viols.append(Violation(
                        "lock-order", rel, t.line, owner_fn(rel, t.line),
                        "bare .lock() — use lock_pool/lock_recover"))

    # ---- rule 4: overflow intent in ops/ ----
    WRAP_PREFIX = ("wrapping_", "saturating_", "checked_", "overflowing_")
    for rel, toks in file_toks.items():
        if not rel.startswith("ops/"):
            continue
        in_test = file_tests[rel]
        directives = file_dirs[rel]
        # per-line: has ovf marker / has explicit-intent method. A
        # standalone `// ovf:` comment covers the next token-bearing
        # line (up to 5 lines below, so continuation comments are ok);
        # an end-of-line `// ovf:` covers its own line.
        token_lines = {t.line for t in toks}
        marked = set()
        for line, ds in directives.items():
            for d in ds:
                if d.startswith("ovf:") and d[4:].strip():
                    marked.add(line)
                    for j in range(line + 1, line + 6):
                        if j in token_lines:
                            marked.add(j)
                            break
        explicit = {}
        for t in toks:
            if t.kind == IDENT and t.text.startswith(WRAP_PREFIX):
                explicit[t.line] = True
        # assertion-macro argument spans are specification, not kernel
        # arithmetic — exempt (debug builds check them anyway)
        ASSERT_MACROS = {"assert", "assert_eq", "assert_ne",
                         "debug_assert", "debug_assert_eq",
                         "debug_assert_ne"}
        in_assert = [False] * len(toks)
        i = 0
        while i < len(toks):
            t = toks[i]
            if t.kind == IDENT and t.text in ASSERT_MACROS \
                    and i + 2 < len(toks) and toks[i + 1].text == "!" \
                    and toks[i + 2].text == "(":
                j = i + 3
                pd = 1
                while j < len(toks) and pd > 0:
                    if toks[j].text == "(":
                        pd += 1
                    elif toks[j].text == ")":
                        pd -= 1
                    j += 1
                for k in range(i, j):
                    in_assert[k] = True
                i = j
                continue
            i += 1
        bracket = 0
        attr = 0
        for i, t in enumerate(toks):
            if t.kind != PUNCT:
                continue
            if t.text == "#" and i + 1 < len(toks) \
                    and toks[i + 1].text == "[":
                attr += 1
            if t.text == "[":
                bracket += 1
                continue
            if t.text == "]":
                bracket -= 1
                if attr > 0:
                    attr -= 1
                continue
            if in_test[i] or bracket > 0 or in_assert[i]:
                continue
            prev = toks[i - 1] if i > 0 else None
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            binary_prev = prev is not None and (
                prev.kind in (IDENT, INT, FLOAT)
                and prev.text not in KEYWORDS
                or prev.text in (")", "]"))
            bad = False
            if t.text in ("+", "-", "*") and binary_prev:
                bad = True
            elif t.text in ("+=", "-=", "*=", "<<=", ">>="):
                bad = True
            elif t.text in ("<<", ">>"):
                if binary_prev and nxt is not None and (
                        nxt.kind in (IDENT, INT)
                        or nxt.text in ("(", "-")):
                    bad = True
            if not bad:
                continue
            if t.line in marked or explicit.get(t.line):
                continue
            if allowed(allow, "overflow-intent", rel,
                       owner_fn(rel, t.line), t.text):
                continue
            viols.append(Violation(
                "overflow-intent", rel, t.line, owner_fn(rel, t.line),
                f"bare `{t.text}` without an `// ovf:` bound "
                f"justification or explicit wrapping_/saturating_/"
                f"checked_ intent"))

    # ---- rule 5: hot-path discipline in trace/timeseries.rs ----
    # Per-wave sampling sites (`sample*` / `record*`) run inside
    # Batcher::step on every wave: preallocated rings only, Relaxed-only
    # atomics. Export paths (snapshot/to_json) are out of scope.
    ALLOC_TYPES = {"Vec", "String", "Box", "VecDeque", "BTreeMap",
                   "HashMap"}
    ALLOC_MACROS = {"vec", "format"}
    ALLOC_METHODS = {"to_vec", "to_string", "to_owned", "collect",
                     "push", "extend", "reserve", "insert",
                     "with_capacity"}
    for fn in registry.values():
        if fn.is_test or fn.path != "trace/timeseries.rs" \
                or not (fn.name.startswith("sample")
                        or fn.name.startswith("record")):
            continue
        toks = fn.body
        for i, t in enumerate(toks):
            if t.kind != IDENT:
                continue
            nxt = toks[i + 1].text if i + 1 < len(toks) else ""
            msg = None
            if i >= 2 and toks[i - 2].text == "Ordering" \
                    and toks[i - 1].text == "::" and t.text != "Relaxed":
                msg = (f"Ordering::{t.text} in a per-wave sampling site "
                       f"— hot-path atomics must be Relaxed")
            elif t.text in ALLOC_TYPES and nxt == "::":
                msg = (f"{t.text}:: constructor in a per-wave sampling "
                       f"site — preallocate in the TimeSeries "
                       f"constructor")
            elif t.text in ALLOC_MACROS and nxt == "!":
                msg = f"{t.text}! allocates in a per-wave sampling site"
            elif t.text in ALLOC_METHODS and i >= 1 \
                    and toks[i - 1].text == "." and nxt == "(":
                msg = (f".{t.text}() may allocate in a per-wave "
                       f"sampling site")
            if msg is None:
                continue
            if allowed(allow, "hot-path", fn.path, fn.qname, t.text):
                continue
            viols.append(Violation(
                "hot-path", fn.path, t.line, fn.qname, msg))

    for e in allow:
        if not e.get("_used"):
            viols.append(Violation(
                "allowlist", allow_path, 0, e.get("item", "-"),
                f"stale allow entry (never matched): {e.get('rule')} "
                f"{e.get('file')} {e.get('item', '')}"))

    viols.sort(key=lambda v: (v.rule, v.path, v.line))
    for v in viols:
        print(v)
    print(f"\n{len(viols)} violation(s)")
    sys.exit(1 if viols else 0)


if __name__ == "__main__":
    main()
