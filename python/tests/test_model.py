"""L2 model tests: shapes, fp-vs-int fidelity, param contracts, and the
outlier-injection substrate's function preservation."""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from compile import intops, train
from compile.model import (ModelConfig, PRESETS, QuantScheme, fp_forward,
                           fp_param_spec, init_params, int_forward,
                           int_param_spec, int_params_from_fp)
from compile.intops import I32

TOKS = jnp.asarray(np.random.default_rng(3).integers(0, 256, 40), I32)


@pytest.mark.parametrize("name", ["tinyllama_s", "tinyopt_s"])
def test_fp_forward_shapes(name):
    cfg = PRESETS[name]
    params = init_params(cfg, 0)
    out = fp_forward(cfg, params, TOKS)
    assert out.shape == (40, cfg.vocab)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("name", ["tinyllama_s", "tinyopt_s"])
def test_int_forward_tracks_fp_w8a8(name):
    cfg = PRESETS[name]
    params = init_params(cfg, 1)
    sch = QuantScheme(8, 8)
    fp = np.asarray(fp_forward(cfg, params, TOKS))
    qp = int_params_from_fp(cfg, params, sch)
    iq = np.asarray(int_forward(cfg, qp, TOKS, sch))
    corr = np.corrcoef(fp.ravel(), iq.ravel())[0, 1]
    assert corr > 0.85, f"{name} w8a8 corr {corr}"


def test_w4a4_degrades_more_than_w8a8():
    cfg = PRESETS["tinyllama_s"]
    params = init_params(cfg, 2)
    fp = np.asarray(fp_forward(cfg, params, TOKS))
    errs = {}
    for wb, ab in [(8, 8), (4, 4)]:
        sch = QuantScheme(wb, ab)
        qp = int_params_from_fp(cfg, params, sch)
        iq = np.asarray(int_forward(cfg, qp, TOKS, sch))
        errs[(wb, ab)] = float(np.abs(fp - iq).mean())
    assert errs[(4, 4)] > errs[(8, 8)] * 1.5


def test_param_specs_complete_and_ordered():
    for name, cfg in PRESETS.items():
        fps = fp_param_spec(cfg)
        names = [n for n, _ in fps]
        assert len(set(names)) == len(names), f"dup fp params {name}"
        params = init_params(cfg, 0)
        assert set(names) == set(params.keys())
        ints = int_param_spec(cfg)
        inames = [n for n, _, _ in ints]
        assert len(set(inames)) == len(inames), f"dup int params {name}"
        qp = int_params_from_fp(cfg, params, QuantScheme(8, 8))
        missing = [n for n, _, _ in ints if n not in qp]
        assert not missing, f"{name} missing {missing}"
        for n, shape, _dt in ints:
            got = tuple(np.asarray(qp[n]).shape)
            assert got == tuple(shape), f"{name} {n}: {got} vs {shape}"


def test_outlier_injection_preserves_function():
    cfg = PRESETS["tinyllama_s"]
    params = init_params(cfg, 4)
    fp0 = np.asarray(fp_forward(cfg, params, TOKS))
    inj = train.inject_outliers(cfg, params)
    fp1 = np.asarray(fp_forward(cfg, inj, TOKS))
    scale = np.abs(fp0).max()
    assert np.abs(fp0 - fp1).max() < scale * 2e-2 + 1e-3


def test_outlier_injection_creates_channel_imbalance():
    cfg = PRESETS["tinyllama_s"]
    params = init_params(cfg, 4)
    inj = train.inject_outliers(cfg, params)
    g0 = np.asarray(params["layers.0.norm1.g"])
    g1 = np.asarray(inj["layers.0.norm1.g"])
    def imb(g):
        s = np.sort(np.abs(g))
        return s[-1] / max(np.median(s), 1e-9)
    assert imb(g1) > imb(g0) * 4, (imb(g0), imb(g1))


def test_weights_roundtrip(tmp_path):
    cfg = dataclasses.replace(PRESETS["tinyllama_s"], n_layers=1)
    params = init_params(cfg, 5)
    path = str(tmp_path / "w.bin")
    train.save_weights(path, params, {"config": cfg.to_dict(), "x": 1})
    loaded, meta = train.load_weights(path)
    assert meta["x"] == 1
    assert ModelConfig.from_dict(meta["config"]) == cfg
    for k, v in params.items():
        np.testing.assert_array_equal(loaded[k], v)


def test_block_config_slices_model():
    """The int_block artifact contract: an n_layers=1 config over the
    same weights must match the full model's layer-0 semantics."""
    cfg = PRESETS["tinyllama_s"]
    bcfg = dataclasses.replace(cfg, n_layers=1)
    params = init_params(cfg, 6)
    sch = QuantScheme(8, 8)
    qp_full = int_params_from_fp(cfg, params, sch)
    qp_block = int_params_from_fp(bcfg, params, sch)
    # layer-0 quantized weights identical
    for suffix in ["attn.wq.wq", "mlp.wg.mw", "alpha_m"]:
        np.testing.assert_array_equal(
            np.asarray(qp_full[f"layers.0.{suffix}"]),
            np.asarray(qp_block[f"layers.0.{suffix}"]))
    out = int_forward(bcfg, qp_block, TOKS, sch)
    assert out.shape == (40, cfg.vocab)


def test_corpus_deterministic():
    from compile import corpus

    a = corpus.generate(5000, 42)
    b = corpus.generate(5000, 42)
    assert a == b
    c = corpus.generate(5000, 43)
    assert a != c
    tr, va = corpus.train_val_split(a)
    assert tr + va == a
    # split snaps to the previous paragraph boundary, so the val
    # fraction overshoots 10% by up to one paragraph on tiny inputs
    assert 0.05 < len(va) / len(a) < 0.25
