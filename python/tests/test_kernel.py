"""L1 correctness: pallas kernels vs the intops spec (bit-exact) and
the intops spec vs float oracles (error-bounded). Hypothesis sweeps
shapes/values; the paper's error bounds anchor the tolerances."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import intops
from compile.intops import I32, I64
from compile.kernels import ref
from compile.kernels.di_exp import di_exp as pl_exp
from compile.kernels.di_matmul import di_matmul as pl_matmul
from compile.kernels.di_norm import di_norm as pl_norm
from compile.kernels.di_softmax import di_clipped_softmax as pl_softmax
from compile.kernels.di_swiglu import di_swiglu as pl_swiglu

SET = dict(max_examples=12, deadline=None)


def quant_mat(rng, t, n, scale=2.0, bits=8):
    x = rng.normal(0, scale, (t, n))
    return intops.quantize_f32(jnp.asarray(x), bits), x


# ---------------------------------------------------------------------------
# pallas == spec (bit-exact)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(t=st.integers(1, 33), k=st.integers(4, 48), n=st.integers(2, 24),
       seed=st.integers(0, 10_000), block=st.sampled_from([4, 16, 64]))
def test_pallas_matmul_bitexact(t, k, n, seed, block):
    rng = np.random.default_rng(seed)
    (xv, m, kx, zp), _ = quant_mat(rng, t, k)
    w = rng.normal(0, 0.2, (k, n))
    sc = np.maximum(np.abs(w).max(axis=0), 1e-8) / 127.0
    mw, kw = intops.align_channel_scales(jnp.asarray(sc))
    wq = jnp.clip(jnp.floor(jnp.asarray(w) / (np.asarray(mw) /
                  np.exp2(float(kw)))[None, :] + 0.5), -127, 127).astype(I32)
    want = intops.di_linear(xv, m, kx, zp, wq, mw, kw, None, 8)
    got = pl_matmul(xv, m, kx, zp, wq, mw, int(kw), 8, block_t=block)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(**SET)
@given(t=st.integers(1, 40), n=st.integers(1, 32), seed=st.integers(0, 9999))
def test_pallas_exp_bitexact(t, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-3000, 1, (t, n)), I32)
    m = jnp.asarray(rng.integers(128, 256, t), I32)
    k = jnp.asarray(rng.integers(4, 16, t), I32)
    np.testing.assert_array_equal(
        np.asarray(intops.di_exp(x, m, k)), np.asarray(pl_exp(x, m, k)))


@settings(**SET)
@given(t=st.integers(1, 24), s=st.integers(2, 24), seed=st.integers(0, 9999))
def test_pallas_softmax_bitexact(t, s, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(0, 5e5, (t, s)).astype(np.int64), I64)
    m1 = jnp.asarray(rng.integers(128, 256, t), I32)
    k1 = jnp.asarray(rng.integers(10, 18, t), I32)
    mask = jnp.asarray(np.tril(np.ones((t, s), bool), s // 2))
    want = intops.di_clipped_softmax(p, m1, k1, 177, 13, 8, mask=mask)
    got = pl_softmax(p, m1, k1, mask, 177, 13, 8)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@settings(**SET)
@given(t=st.integers(1, 24), n=st.integers(2, 48), seed=st.integers(0, 9999),
       centered=st.booleans())
def test_pallas_norm_bitexact(t, n, seed, centered):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, (t, n)), I32)
    zp = jnp.asarray(rng.integers(80, 170, t), I32)
    want = intops.di_norm(x, zp, 8, centered)
    got = pl_norm(x, zp, centered, 8)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(**SET)
@given(t=st.integers(1, 16), n=st.integers(2, 32), seed=st.integers(0, 9999))
def test_pallas_swiglu_bitexact(t, n, seed):
    rng = np.random.default_rng(seed)
    (gv, gm, gk, gzp), _ = quant_mat(rng, t, n, 2.5)
    (uv, um, uk, uzp), _ = quant_mat(rng, t, n, 1.0)
    am = jnp.asarray(rng.integers(100, 256, n), I32)
    ak = jnp.asarray(rng.integers(4, 10, n), I32)
    want = intops.di_swiglu(gv, gm, gk, gzp, uv, um, uk, uzp, am, ak, 8, 8)
    got = pl_swiglu(gv, gm, gk, gzp, uv, um, uk, uzp, am, ak)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# spec vs float oracles (error-bounded)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(seed=st.integers(0, 9999), t=st.integers(2, 12),
       n=st.integers(8, 64))
def test_linear_tracks_float(seed, t, n):
    rng = np.random.default_rng(seed)
    (xv, m, kx, zp), x = quant_mat(rng, t, n)
    w = rng.normal(0, 0.2, (n, 12))
    sc = np.maximum(np.abs(w).max(axis=0), 1e-8) / 127.0
    mw, kw = intops.align_channel_scales(jnp.asarray(sc))
    wq = jnp.clip(jnp.floor(jnp.asarray(w) / (np.asarray(mw) /
                  np.exp2(float(kw)))[None, :] + 0.5), -127, 127).astype(I32)
    out = intops.di_linear(xv, m, kx, zp, wq, mw, kw, None, 8)
    got = np.asarray(ref.dequant(*out))
    want = np.asarray(ref.linear(jnp.asarray(x), jnp.asarray(w)))
    amax = np.abs(want).max() + 1e-6
    assert np.abs(got - want).max() < amax * 0.04 + 0.05


@settings(**SET)
@given(seed=st.integers(0, 9999))
def test_softmax_error_bound(seed):
    """Paper: clipped softmax max error bounded by the window/255 plus
    the DI-Exp approximation (<= ~0.06 total)."""
    rng = np.random.default_rng(seed)
    t, s = 6, 20
    p = jnp.asarray(rng.normal(0, 8e5, (t, s)).astype(np.int64), I64)
    m1 = jnp.asarray(rng.integers(128, 256, t), I32)
    k1 = jnp.asarray(rng.integers(10, 14, t), I32)
    y = intops.di_clipped_softmax(p, m1, k1, 200, 12, 8)
    sc = (np.asarray(m1, np.float64) * 200 /
          np.exp2(np.asarray(k1) + 12.0))[:, None]
    want = np.asarray(ref.softmax(np.asarray(p) * sc))
    got = np.asarray(y) / 128.0
    assert np.abs(got - want).max() < 0.065


@settings(**SET)
@given(seed=st.integers(0, 9999), centered=st.booleans())
def test_norm_tracks_float(seed, centered):
    rng = np.random.default_rng(seed)
    (xv, m, k, zp), _ = quant_mat(rng, 5, 48, 3.0)
    out = intops.di_norm(xv, zp, 8, centered)
    got = np.asarray(ref.dequant(*out))
    xd = np.asarray(ref.dequant(xv, m, k, zp))
    want = np.asarray(
        ref.layernorm(xd) if centered else ref.rmsnorm(xd))
    assert np.abs(got - want).max() < 0.08


@settings(**SET)
@given(seed=st.integers(0, 9999))
def test_swiglu_tracks_float(seed):
    rng = np.random.default_rng(seed)
    (gv, gm, gk, gzp), _ = quant_mat(rng, 4, 24, 2.0)
    (uv, um, uk, uzp), _ = quant_mat(rng, 4, 24, 1.0)
    am = jnp.full((24,), 1, I32)
    ak = jnp.zeros((24,), I32)
    out = intops.di_swiglu(gv, gm, gk, gzp, uv, um, uk, uzp, am, ak, 8, 8)
    got = np.asarray(ref.dequant(*out))
    gd = ref.dequant(gv, gm, gk, gzp)
    ud = ref.dequant(uv, um, uk, uzp)
    want = np.asarray(ref.swiglu(gd, ud))
    amax = np.abs(want).max() + 1e-6
    assert np.abs(got - want).max() < amax * 0.3 + 0.08


@settings(**SET)
@given(seed=st.integers(0, 9999), bits=st.sampled_from([4, 6, 8]))
def test_requant_roundtrip(seed, bits):
    rng = np.random.default_rng(seed)
    # ranges chosen so the output scale stays representable in the
    # paper's 8-bit dyadic format: s_out = m/2^k with k >= 0 caps the
    # float range at qmax*255 (~3.8k even at 4 bits)
    p = jnp.asarray(rng.integers(-(1 << 17), 1 << 17, (4, 16)), I64)
    m = jnp.asarray(rng.integers(128, 256, 4).astype(np.int64), I64)
    k = jnp.asarray(rng.integers(14, 19, 4), I32)
    v, my, ky, zp = intops.requant_rows(p, m, k, bits)
    s_in = np.asarray(m, np.float64) / np.exp2(np.asarray(k))
    s_out = np.asarray(my, np.float64) / np.exp2(np.asarray(ky))
    want = np.asarray(p) * s_in[:, None]
    got = (np.asarray(v) - np.asarray(zp)[:, None]) * s_out[:, None]
    # <= 1 output step from value + zero-point rounding, plus up to
    # ~1/128 relative from the dyadic mantissa FLOOR of Eq. 7
    step = s_out[:, None] * 1.05 + np.abs(want) * 0.02
    assert (np.abs(want - got) <= step + 1e-9).all()
    # values must fill the range (dynamic quantization)
    qmax = (1 << bits) - 1
    assert np.asarray(v).max() <= qmax and np.asarray(v).min() >= 0


def test_isqrt_and_ilog2_exact():
    xs = np.concatenate([np.arange(0, 300),
                         2 ** np.arange(0, 60, dtype=np.int64)])
    got = np.asarray(intops.isqrt(jnp.asarray(xs)))
    want = np.floor(np.sqrt(xs.astype(np.float64) + 1e-12)).astype(np.int64)
    np.testing.assert_array_equal(got, want)
    xs2 = xs[xs >= 1]
    got2 = np.asarray(intops.ilog2(jnp.asarray(xs2)))
    want2 = np.floor(np.log2(xs2.astype(np.float64))).astype(np.int64)
    np.testing.assert_array_equal(got2, want2)


def test_rope_orthogonality():
    cos_q, sin_q = intops.rope_tables(16, 32)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 256, (32, 2, 16)),
                    I32)
    zp = jnp.full((32,), 128, I32)
    y = intops.di_rope(x, zp, jnp.asarray(cos_q), jnp.asarray(sin_q))
    xc = np.asarray(x, np.int64) - 128
    n0 = (xc ** 2).sum(axis=-1)
    n1 = (np.asarray(y, np.int64) ** 2).sum(axis=-1)
    rel = np.abs(n1 - n0) / np.maximum(n0, 1)
    assert rel.max() < 0.03


def test_clip_value_effect():
    """Larger clip c widens the represented window (Table 5 mechanics)."""
    rng = np.random.default_rng(5)
    p = jnp.asarray(rng.normal(0, 1e6, (1, 32)).astype(np.int64), I64)
    m1 = jnp.asarray([255], I32)
    k1 = jnp.asarray([8], I32)
    outs = {}
    for c, (cm, ck) in {10: (160, 4), 15: (240, 4), 20: (160, 3)}.items():
        y = intops.di_clipped_softmax(p, m1, k1, 255, 8, 8,
                                      clip=(cm, ck))
        outs[c] = int((np.asarray(y) > 0).sum())
    # wider clip keeps more non-zero probabilities
    assert outs[10] <= outs[15] <= outs[20] + 1
