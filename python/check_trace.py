#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file written by the rust trace
layer (ILLM_TRACE=out.json) — the `make trace-smoke` gate.

Checks, in order:
  * top-level shape: {"traceEvents": [...], "displayTimeUnit": "ms"}
  * every event carries name/cat/ph/ts/pid/tid with sane types;
    'X' events carry a non-negative dur, 'i' events scope s == "g",
    'C' events (Perfetto counter tracks) carry a numeric args.value
  * at least one request traverses the FULL lifecycle chain
    queued -> admitted -> prefill-chunk -> decode-wave -> finished
    (matched through args.req)
  * at least one per-layer phase event (cat == "phase") exists
  * decode waves are continuously batched: whenever any per-request
    decode-wave span exists, at least one wave-level "decode-batch"
    span (cat == "engine", the single batched forward every
    decode-wave of that step shares) must exist too
  * counter tracks: every 'C' name is one of the 16 known time-series
    (KNOWN_COUNTERS, mirroring rust TS_SERIES); per-name timestamps
    are non-decreasing; and if the trace shows decode waves (the
    batcher ran) all 16 tracks must be present — the per-wave sampler
    fires on every `Batcher::step`
  * graceful degradation (vacuous when no faults occurred): every
    preempted request resolves — it is later restored ("restoring",
    emitted when it checkpointed generated tokens) and finishes, or
    is rejected with a typed reason; "restoring" only ever follows a
    preemption; no request is both rejected and finished

Stdlib only (the container has no extra wheels). Exit 0 on success
with a one-line summary; exit 1 with "check_trace: FAIL: ..." on the
first violation. `--self-test` runs the checker against built-in
good/bad fixtures instead of a file.
"""

import json
import sys

LIFECYCLE = ("queued", "admitted", "prefill-chunk", "decode-wave",
             "finished")

# Mirror of rust/src/trace/timeseries.rs TS_SERIES, in slot order.
KNOWN_COUNTERS = (
    "kv_pages_used",
    "kv_pages_free",
    "prefix_pinned_pages",
    "active_seqs",
    "queued_seqs",
    "preempted_total",
    "decode_batch_width",
    "scratch_free",
    "decode_tokens_wave",
    "prefill_tokens_wave",
    "wave_dur_us",
    "decode_tok_per_s",
    "prefill_tok_per_s",
    "sat_events_wave",
    "softmax_rows_wave",
    "softmax_clipped_wave",
)


class CheckFailure(Exception):
    """A named trace-validation violation."""


def fail(msg):
    raise CheckFailure(msg)


def check_event(i, e):
    if not isinstance(e, dict):
        fail(f"event {i} is not an object")
    for key, types in (("name", str), ("cat", str), ("ph", str),
                       ("ts", (int, float)), ("pid", int),
                       ("tid", int)):
        if key not in e:
            fail(f"event {i} ({e.get('name', '?')}) missing {key!r}")
        if not isinstance(e[key], types):
            fail(f"event {i} {key!r} has type "
                 f"{type(e[key]).__name__}")
    if e["ph"] == "X":
        if not isinstance(e.get("dur"), (int, float)):
            fail(f"event {i} ({e['name']}): 'X' without numeric dur")
        if e["dur"] < 0:
            fail(f"event {i} ({e['name']}): negative dur {e['dur']}")
    elif e["ph"] == "i":
        if e.get("s") != "g":
            fail(f"event {i} ({e['name']}): instant scope {e.get('s')!r}"
                 " != 'g'")
    elif e["ph"] == "C":
        v = e.get("args", {}).get("value") \
            if isinstance(e.get("args"), dict) else None
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            fail(f"event {i} ({e['name']}): 'C' without numeric "
                 "args.value")
    else:
        fail(f"event {i} ({e['name']}): unexpected ph {e['ph']!r}")
    if "args" in e and not isinstance(e["args"], dict):
        fail(f"event {i} ({e['name']}): args is not an object")


def validate(doc):
    """Validate a parsed trace document; return the summary line.

    Raises CheckFailure on the first violation.
    """
    if not isinstance(doc, dict):
        fail("top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("traceEvents missing or not an array")
    if not events:
        fail("traceEvents is empty")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit {doc.get('displayTimeUnit')!r} != 'ms'")

    per_req = {}  # req id -> set of lifecycle event names
    n_phase = 0
    n_decode_wave = 0
    n_decode_batch = 0
    # counter tracks: name -> [ts...] in file order
    counters = {}
    # degradation bookkeeping: req id -> set of degradation events,
    # plus whether any preemption checkpointed generated tokens
    degrade = {}
    preempted_with_tokens = set()
    for i, e in enumerate(events):
        check_event(i, e)
        if e["ph"] == "C":
            if e["name"] not in KNOWN_COUNTERS:
                fail(f"event {i}: unknown counter track "
                     f"{e['name']!r} (not in the {len(KNOWN_COUNTERS)} "
                     "known time-series)")
            counters.setdefault(e["name"], []).append(e["ts"])
            continue
        if e["cat"] == "phase":
            n_phase += 1
        if e["name"] == "decode-wave":
            n_decode_wave += 1
        if e["name"] == "decode-batch":
            if e["cat"] != "engine":
                fail(f"event {i}: decode-batch cat {e['cat']!r} "
                     "!= 'engine'")
            n_decode_batch += 1
        req = e.get("args", {}).get("req")
        if req is not None and e["name"] in LIFECYCLE:
            per_req.setdefault(req, set()).add(e["name"])
        if req is not None and e["name"] in ("preempted", "restoring",
                                             "rejected", "finished"):
            degrade.setdefault(req, set()).add(e["name"])
            if (e["name"] == "preempted"
                    and e.get("args", {}).get("generated", 0) > 0):
                preempted_with_tokens.add(req)

    complete = [r for r, names in sorted(per_req.items())
                if names.issuperset(LIFECYCLE)]
    if not complete:
        seen = {r: sorted(n) for r, n in sorted(per_req.items())}
        fail("no request carries the full lifecycle chain "
             f"{' -> '.join(LIFECYCLE)}; saw {seen}")
    if n_phase == 0:
        fail("no per-layer phase events (cat == 'phase')")
    if n_decode_wave > 0 and n_decode_batch == 0:
        fail(f"{n_decode_wave} decode-wave spans but no wave-level "
             "'decode-batch' span — decode ran outside the batched "
             "path")

    # counter tracks: per-name monotone timestamps; batcher ran =>
    # the per-wave sampler must have emitted every known series
    n_counter_samples = 0
    for name, tss in sorted(counters.items()):
        n_counter_samples += len(tss)
        for a, b in zip(tss, tss[1:]):
            if b < a:
                fail(f"counter track {name!r}: timestamps go "
                     f"backwards ({a} -> {b})")
    if n_decode_wave > 0:
        missing = [n for n in KNOWN_COUNTERS if n not in counters]
        if missing:
            fail(f"decode waves ran but {len(missing)} counter "
                 f"track(s) missing: {', '.join(missing)}")

    # graceful-degradation chain (vacuously true without faults):
    # preempt -> restore -> finished, or a typed rejection
    n_preempt = n_restore = n_reject = 0
    for req, names in sorted(degrade.items()):
        if "preempted" in names:
            n_preempt += 1
            if not names & {"finished", "rejected"}:
                fail(f"req {req} was preempted but never finished "
                     "nor rejected (lost request)")
            if (req in preempted_with_tokens
                    and not names & {"restoring", "rejected"}):
                fail(f"req {req} was preempted with generated tokens "
                     "but never restored nor rejected")
        if "restoring" in names:
            n_restore += 1
            if "preempted" not in names:
                fail(f"req {req} has a 'restoring' event without a "
                     "preceding preemption")
        if "rejected" in names:
            n_reject += 1
            if "finished" in names:
                fail(f"req {req} is both rejected and finished")

    return (f"{len(events)} events, "
            f"{len(complete)}/{len(per_req)} requests with the full "
            f"lifecycle chain, {n_phase} phase events, "
            f"{n_decode_batch} batched decode waves, "
            f"{len(counters)} counter tracks "
            f"({n_counter_samples} samples), "
            f"{n_preempt} preemptions / {n_restore} restores / "
            f"{n_reject} rejections")


# --------------------------------------------------------- self-test

def _span(name, cat, ts, dur=1.0, **args):
    e = {"name": name, "cat": cat, "ph": "X", "ts": ts, "dur": dur,
         "pid": 1, "tid": 0}
    if args:
        e["args"] = args
    return e


def _counter(name, ts, value):
    return {"name": name, "cat": "timeseries", "ph": "C", "ts": ts,
            "pid": 1, "tid": 0, "args": {"value": value}}


def _good_doc():
    ev = [
        _span("queued", "lifecycle", 1.0, req=0),
        _span("admitted", "lifecycle", 2.0, req=0),
        _span("prefill-chunk", "lifecycle", 3.0, req=0),
        _span("layer", "phase", 3.5),
        _span("decode-batch", "engine", 4.0),
        _span("decode-wave", "lifecycle", 4.0, req=0),
        _span("finished", "lifecycle", 5.0, req=0),
    ]
    for t in (6.0, 7.0):
        for i, name in enumerate(KNOWN_COUNTERS):
            ev.append(_counter(name, t, i))
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def self_test():
    doc = _good_doc()
    try:
        validate(doc)
    except CheckFailure as e:
        print(f"check_trace: FAIL: self-test good fixture rejected: {e}")
        return 1

    def expect_fail(tag, mutate):
        d = _good_doc()
        mutate(d)
        try:
            validate(d)
        except CheckFailure:
            return None
        return f"self-test bad fixture {tag!r} was accepted"

    def drop_counter(d):
        d["traceEvents"] = [e for e in d["traceEvents"]
                            if not (e["ph"] == "C"
                                    and e["name"] == "kv_pages_free")]

    def unknown_counter(d):
        d["traceEvents"].append(_counter("bogus_series", 8.0, 1))

    def backwards_ts(d):
        d["traceEvents"].append(_counter("kv_pages_used", 0.5, 1))

    def no_value(d):
        d["traceEvents"].append(
            {"name": "kv_pages_used", "cat": "timeseries", "ph": "C",
             "ts": 9.0, "pid": 1, "tid": 0, "args": {}})

    def bad_ph(d):
        d["traceEvents"].append(
            {"name": "x", "cat": "c", "ph": "Z", "ts": 9.0,
             "pid": 1, "tid": 0})

    def broken_chain(d):
        d["traceEvents"] = [e for e in d["traceEvents"]
                            if e["name"] != "admitted"]

    for tag, mutate in (("missing-counter-track", drop_counter),
                        ("unknown-counter-name", unknown_counter),
                        ("backwards-counter-ts", backwards_ts),
                        ("counter-without-value", no_value),
                        ("unexpected-ph", bad_ph),
                        ("broken-lifecycle", broken_chain)):
        err = expect_fail(tag, mutate)
        if err:
            print(f"check_trace: FAIL: {err}")
            return 1
    print("check_trace: OK: self-test passed (1 good, 6 bad fixtures)")
    return 0


def main():
    if len(sys.argv) == 2 and sys.argv[1] == "--self-test":
        sys.exit(self_test())
    if len(sys.argv) != 2:
        print("usage: check_trace.py <trace.json> | --self-test")
        sys.exit(2)
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_trace: FAIL: cannot load {path}: {e}")
        sys.exit(1)
    try:
        summary = validate(doc)
    except CheckFailure as e:
        print(f"check_trace: FAIL: {e}")
        sys.exit(1)
    print(f"check_trace: OK: {summary}")


if __name__ == "__main__":
    main()
