#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file written by the rust trace
layer (ILLM_TRACE=out.json) — the `make trace-smoke` gate.

Checks, in order:
  * top-level shape: {"traceEvents": [...], "displayTimeUnit": "ms"}
  * every event carries name/cat/ph/ts/pid/tid with sane types;
    'X' events carry a non-negative dur, 'i' events scope s == "g"
  * at least one request traverses the FULL lifecycle chain
    queued -> admitted -> prefill-chunk -> decode-wave -> finished
    (matched through args.req)
  * at least one per-layer phase event (cat == "phase") exists
  * decode waves are continuously batched: whenever any per-request
    decode-wave span exists, at least one wave-level "decode-batch"
    span (cat == "engine", the single batched forward every
    decode-wave of that step shares) must exist too
  * graceful degradation (vacuous when no faults occurred): every
    preempted request resolves — it is later restored ("restoring",
    emitted when it checkpointed generated tokens) and finishes, or
    is rejected with a typed reason; "restoring" only ever follows a
    preemption; no request is both rejected and finished

Stdlib only (the container has no extra wheels). Exit 0 on success
with a one-line summary; exit 1 with "check_trace: FAIL: ..." on the
first violation.
"""

import json
import sys

LIFECYCLE = ("queued", "admitted", "prefill-chunk", "decode-wave",
             "finished")


def fail(msg):
    print(f"check_trace: FAIL: {msg}")
    sys.exit(1)


def check_event(i, e):
    if not isinstance(e, dict):
        fail(f"event {i} is not an object")
    for key, types in (("name", str), ("cat", str), ("ph", str),
                       ("ts", (int, float)), ("pid", int),
                       ("tid", int)):
        if key not in e:
            fail(f"event {i} ({e.get('name', '?')}) missing {key!r}")
        if not isinstance(e[key], types):
            fail(f"event {i} {key!r} has type "
                 f"{type(e[key]).__name__}")
    if e["ph"] == "X":
        if not isinstance(e.get("dur"), (int, float)):
            fail(f"event {i} ({e['name']}): 'X' without numeric dur")
        if e["dur"] < 0:
            fail(f"event {i} ({e['name']}): negative dur {e['dur']}")
    elif e["ph"] == "i":
        if e.get("s") != "g":
            fail(f"event {i} ({e['name']}): instant scope {e.get('s')!r}"
                 " != 'g'")
    else:
        fail(f"event {i} ({e['name']}): unexpected ph {e['ph']!r}")
    if "args" in e and not isinstance(e["args"], dict):
        fail(f"event {i} ({e['name']}): args is not an object")


def main():
    if len(sys.argv) != 2:
        print("usage: check_trace.py <trace.json>")
        sys.exit(2)
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict):
        fail("top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("traceEvents missing or not an array")
    if not events:
        fail("traceEvents is empty")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit {doc.get('displayTimeUnit')!r} != 'ms'")

    per_req = {}  # req id -> set of lifecycle event names
    n_phase = 0
    n_decode_wave = 0
    n_decode_batch = 0
    # degradation bookkeeping: req id -> set of degradation events,
    # plus whether any preemption checkpointed generated tokens
    degrade = {}
    preempted_with_tokens = set()
    for i, e in enumerate(events):
        check_event(i, e)
        if e["cat"] == "phase":
            n_phase += 1
        if e["name"] == "decode-wave":
            n_decode_wave += 1
        if e["name"] == "decode-batch":
            if e["cat"] != "engine":
                fail(f"event {i}: decode-batch cat {e['cat']!r} "
                     "!= 'engine'")
            n_decode_batch += 1
        req = e.get("args", {}).get("req")
        if req is not None and e["name"] in LIFECYCLE:
            per_req.setdefault(req, set()).add(e["name"])
        if req is not None and e["name"] in ("preempted", "restoring",
                                            "rejected", "finished"):
            degrade.setdefault(req, set()).add(e["name"])
            if (e["name"] == "preempted"
                    and e.get("args", {}).get("generated", 0) > 0):
                preempted_with_tokens.add(req)

    complete = [r for r, names in sorted(per_req.items())
                if names.issuperset(LIFECYCLE)]
    if not complete:
        seen = {r: sorted(n) for r, n in sorted(per_req.items())}
        fail("no request carries the full lifecycle chain "
             f"{' -> '.join(LIFECYCLE)}; saw {seen}")
    if n_phase == 0:
        fail("no per-layer phase events (cat == 'phase')")
    if n_decode_wave > 0 and n_decode_batch == 0:
        fail(f"{n_decode_wave} decode-wave spans but no wave-level "
             "'decode-batch' span — decode ran outside the batched "
             "path")

    # graceful-degradation chain (vacuously true without faults):
    # preempt -> restore -> finished, or a typed rejection
    n_preempt = n_restore = n_reject = 0
    for req, names in sorted(degrade.items()):
        if "preempted" in names:
            n_preempt += 1
            if not names & {"finished", "rejected"}:
                fail(f"req {req} was preempted but never finished "
                     "nor rejected (lost request)")
            if (req in preempted_with_tokens
                    and not names & {"restoring", "rejected"}):
                fail(f"req {req} was preempted with generated tokens "
                     "but never restored nor rejected")
        if "restoring" in names:
            n_restore += 1
            if "preempted" not in names:
                fail(f"req {req} has a 'restoring' event without a "
                     "preceding preemption")
        if "rejected" in names:
            n_reject += 1
            if "finished" in names:
                fail(f"req {req} is both rejected and finished")

    print(f"check_trace: OK: {len(events)} events, "
          f"{len(complete)}/{len(per_req)} requests with the full "
          f"lifecycle chain, {n_phase} phase events, "
          f"{n_decode_batch} batched decode waves, "
          f"{n_preempt} preemptions / {n_restore} restores / "
          f"{n_reject} rejections")


if __name__ == "__main__":
    main()
