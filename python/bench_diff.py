#!/usr/bin/env python3
"""Perf-regression gate over BENCH_serving.json snapshots — the
`make bench-diff` target.

Compares a candidate snapshot against a baseline and exits non-zero
when a tracked metric regresses beyond its tolerance band:

  bench_diff.py base.json new.json        # explicit pair
  bench_diff.py --history BENCH_history/serving.jsonl [--last N]
      # candidate = last line; baseline = per-metric median of up to
      # N preceding lines (default 8) — robust to one noisy run
  bench_diff.py --self-test               # built-in fixtures

Tracked metrics are dotted paths into the snapshot (see METRICS):
throughputs are higher-is-better with a 10% band; latency quantiles
are lower-is-better with a 50% band (they are noisy on shared CI
hardware and the throughput columns already catch real slowdowns).
`--tolerance`/`--latency-tolerance` override the bands.

A metric missing from either side, or non-positive in the baseline,
is skipped — so the committed placeholder snapshots (no toolchain in
the authoring environment, see BENCH_serving.json's note) pass
vacuously with a warning. `--min-metrics K` turns "fewer than K
comparable metrics" into a failure once real snapshots are committed.

Stdlib only. Exit 0 = pass, 1 = regression (or min-metrics unmet),
2 = usage/IO error.
"""

import json
import sys

# (dotted path, direction, default tolerance band)
#   higher: fail when new < base * (1 - tol)
#   lower:  fail when new > base * (1 + tol)
THROUGHPUT_TOL = 0.10
LATENCY_TOL = 0.50
METRICS = (
    ("prefill.rowwise_tok_per_s", "higher", THROUGHPUT_TOL),
    ("prefill.tiled_tok_per_s", "higher", THROUGHPUT_TOL),
    ("prefill.tiled_threaded_tok_per_s", "higher", THROUGHPUT_TOL),
    ("radix.engine_cold_tok_per_s", "higher", THROUGHPUT_TOL),
    ("radix.radix_hit_tok_per_s", "higher", THROUGHPUT_TOL),
    ("decode.decode_wave_tok_per_s", "higher", THROUGHPUT_TOL),
    ("decode.decode_batched_t1_tok_per_s", "higher", THROUGHPUT_TOL),
    ("decode.decode_batched_t4_tok_per_s", "higher", THROUGHPUT_TOL),
    ("serving_int_w8a8_batch8.decode_tok_per_s", "higher",
     THROUGHPUT_TOL),
    ("serving_int_w8a8_batch8.prefill_tok_per_s", "higher",
     THROUGHPUT_TOL),
    ("serving_int_w8a8_batch8.total_tok_per_s", "higher",
     THROUGHPUT_TOL),
    ("serving_int_w8a8_batch8.latency_p50_s", "lower", LATENCY_TOL),
    ("serving_int_w8a8_batch8.latency_p99_s", "lower", LATENCY_TOL),
    ("serving_int_w8a8_batch8.ttft_p95_s", "lower", LATENCY_TOL),
)


def lookup(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    if isinstance(cur, bool) or not isinstance(cur, (int, float)):
        return None
    return float(cur)


def median(xs):
    s = sorted(xs)
    n = len(s)
    if n % 2:
        return s[n // 2]
    return 0.5 * (s[n // 2 - 1] + s[n // 2])


def diff(base, new, tol_throughput, tol_latency, min_metrics,
         base_label="base"):
    """Compare snapshots; returns (exit_code, lines_printed)."""
    lines = []
    failures = 0
    compared = 0
    for path, direction, tol in METRICS:
        if direction == "higher":
            tol = tol_throughput if tol_throughput is not None else tol
        else:
            tol = tol_latency if tol_latency is not None else tol
        b = lookup(base, path)
        n = lookup(new, path)
        if b is None or n is None or b <= 0.0:
            continue
        compared += 1
        rel = (n - b) / b
        if direction == "higher":
            bad = n < b * (1.0 - tol)
            arrow = "-" if rel < 0 else "+"
        else:
            bad = n > b * (1.0 + tol)
            arrow = "+" if rel > 0 else "-"
        verdict = "REGRESSION" if bad else "ok"
        lines.append(
            f"  {'FAIL' if bad else ' ok '} {path}: "
            f"{b:.4g} -> {n:.4g} ({arrow}{abs(rel) * 100.0:.1f}%, "
            f"band {tol * 100.0:.0f}%, {direction} is better) "
            f"{verdict if bad else ''}".rstrip())
        if bad:
            failures += 1
    if compared == 0:
        lines.append(
            "bench_diff: WARN: no comparable metrics between the two "
            "snapshots (placeholder snapshots without measured "
            "sections? run `make bench-json` to regenerate) — passing "
            "vacuously")
        if min_metrics > 0:
            lines.append(
                f"bench_diff: FAIL: 0 comparable metrics < "
                f"--min-metrics {min_metrics}")
            return 1, lines
        return 0, lines
    if compared < min_metrics:
        lines.append(
            f"bench_diff: FAIL: only {compared} comparable metrics < "
            f"--min-metrics {min_metrics}")
        return 1, lines
    if failures:
        lines.append(
            f"bench_diff: FAIL: {failures}/{compared} tracked "
            f"metric(s) regressed vs {base_label}")
        return 1, lines
    lines.append(
        f"bench_diff: OK: {compared} tracked metric(s) within "
        f"tolerance vs {base_label}")
    return 0, lines


def load_json(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot load {path}: {e}")
        sys.exit(2)


def history_pair(path, last_n):
    """Candidate = last jsonl line; baseline = per-metric median of up
    to `last_n` preceding lines, synthesized as a flat dict keyed by
    the METRICS paths."""
    try:
        with open(path, encoding="utf-8") as f:
            rows = [json.loads(ln) for ln in f if ln.strip()]
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot load history {path}: {e}")
        sys.exit(2)
    if len(rows) < 2:
        return None, rows[-1] if rows else None
    cand = rows[-1]
    prior = rows[max(0, len(rows) - 1 - last_n):-1]
    base = {}
    for mpath, _, _ in METRICS:
        vals = [v for v in (lookup(r, mpath) for r in prior)
                if v is not None and v > 0.0]
        if not vals:
            continue
        # rebuild the nested shape so lookup() works on the synth base
        cur = base
        parts = mpath.split(".")
        for part in parts[:-1]:
            cur = cur.setdefault(part, {})
        cur[parts[-1]] = median(vals)
    return base, cand


# --------------------------------------------------------- self-test

def _real_shaped(decode_scale=1.0, p99=0.40):
    return {
        "model": "tinyllama_s", "threads": 4, "smoke": False,
        "prefill": {"rowwise_tok_per_s": 900.0,
                    "tiled_tok_per_s": 1500.0,
                    "tiled_threaded_tok_per_s": 4200.0},
        "radix": {"engine_cold_tok_per_s": 800.0,
                  "radix_hit_tok_per_s": 2600.0},
        "decode": {"decode_wave_tok_per_s": 110.0 * decode_scale,
                   "decode_batched_t1_tok_per_s": 150.0 * decode_scale,
                   "decode_batched_t4_tok_per_s": 430.0 * decode_scale},
        "serving_int_w8a8_batch8": {
            "decode_tok_per_s": 400.0 * decode_scale,
            "prefill_tok_per_s": 3100.0,
            "total_tok_per_s": 3500.0,
            "latency_p50_s": 0.21, "latency_p99_s": p99,
            "ttft_p95_s": 0.12},
    }


def self_test():
    placeholder = {"model": "tinyllama_s", "threads": 4, "smoke": False,
                   "note": "seed snapshot only"}
    cases = [
        # (tag, base, new, expected exit)
        ("identical-pair", _real_shaped(), _real_shaped(), 0),
        ("20pct-decode-drop", _real_shaped(),
         _real_shaped(decode_scale=0.80), 1),
        ("5pct-noise-passes", _real_shaped(),
         _real_shaped(decode_scale=0.95), 0),
        ("improvement-passes", _real_shaped(),
         _real_shaped(decode_scale=1.30), 0),
        ("latency-within-band", _real_shaped(),
         _real_shaped(p99=0.55), 0),
        ("latency-blowup-fails", _real_shaped(),
         _real_shaped(p99=0.70), 1),
        ("placeholder-vacuous-pass", placeholder, placeholder, 0),
    ]
    for tag, base, new, want in cases:
        got, _ = diff(base, new, None, None, 0)
        if got != want:
            print(f"bench_diff: FAIL: self-test {tag!r}: exit {got} "
                  f"!= expected {want}")
            return 1
    # min-metrics turns a vacuous placeholder pass into a failure
    got, _ = diff(placeholder, placeholder, None, None, 1)
    if got != 1:
        print("bench_diff: FAIL: self-test 'min-metrics-enforced': "
              f"exit {got} != 1")
        return 1
    # history mode: median-of-priors baseline catches a last-line drop
    rows = [_real_shaped(), _real_shaped(decode_scale=1.02),
            _real_shaped(decode_scale=0.98),
            _real_shaped(decode_scale=0.75)]
    import tempfile
    import os
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    try:
        with os.fdopen(fd, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
        base, cand = history_pair(path, 8)
        got, _ = diff(base, cand, None, None, 0,
                      base_label="history median")
        if got != 1:
            print("bench_diff: FAIL: self-test 'history-drop': "
                  f"exit {got} != 1")
            return 1
    finally:
        os.unlink(path)
    print(f"bench_diff: OK: self-test passed ({len(cases) + 2} cases)")
    return 0


def main():
    args = sys.argv[1:]
    tol_throughput = None
    tol_latency = None
    min_metrics = 0
    history = None
    last_n = 8
    positional = []
    i = 0
    while i < len(args):
        a = args[i]
        if a == "--self-test":
            sys.exit(self_test())
        elif a == "--tolerance":
            i += 1
            tol_throughput = float(args[i])
        elif a == "--latency-tolerance":
            i += 1
            tol_latency = float(args[i])
        elif a == "--min-metrics":
            i += 1
            min_metrics = int(args[i])
        elif a == "--history":
            i += 1
            history = args[i]
        elif a == "--last":
            i += 1
            last_n = int(args[i])
        else:
            positional.append(a)
        i += 1
    if history is not None:
        base, cand = history_pair(history, last_n)
        if cand is None:
            print(f"bench_diff: WARN: history {history} is empty — "
                  "nothing to gate")
            sys.exit(0)
        if base is None or not base:
            print(f"bench_diff: WARN: history {history} has no prior "
                  "runs with measured metrics — passing vacuously")
            sys.exit(0)
        code, lines = diff(base, cand, tol_throughput, tol_latency,
                           min_metrics, base_label="history median")
    elif len(positional) == 2:
        base = load_json(positional[0])
        new = load_json(positional[1])
        code, lines = diff(base, new, tol_throughput, tol_latency,
                           min_metrics, base_label=positional[0])
    else:
        print("usage: bench_diff.py base.json new.json | "
              "--history FILE [--last N] | --self-test\n"
              "       [--tolerance F] [--latency-tolerance F] "
              "[--min-metrics K]")
        sys.exit(2)
    for ln in lines:
        print(ln)
    sys.exit(code)


if __name__ == "__main__":
    main()
