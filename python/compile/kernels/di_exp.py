"""Pallas kernel: DI-Exp (paper Alg. 1) — shift-only exponential.

Element-wise VPU kernel: no transcendental unit, no multiply-heavy
polynomial — the whole approximation is two shifts, one floor division by
a per-row constant, and one subtraction. Grid over row tiles; each tile
lives in VMEM with its per-row (m, k) scale scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..intops import I32, I64, fdiv, rdiv

DEFAULT_BLOCK_T = 128


def _kernel(x_ref, m_ref, k_ref, o_ref):
    x = x_ref[...].astype(I64)
    m = m_ref[...].astype(I64)[:, None]
    k = k_ref[...][:, None]
    m_f = m + (m >> 1) - (m >> 4)
    two_k = jnp.asarray(1, I64) << jnp.minimum(k, 62).astype(I32)
    t = -jnp.maximum(rdiv(two_k, m_f), 1)
    q = fdiv(x, t)
    r = x - q * t
    unshifted = (r >> 1) - t
    o_ref[...] = (unshifted >> jnp.minimum(q, 62).astype(I32)).astype(I32)


@functools.partial(jax.jit, static_argnames=("block_t",))
def di_exp(x, m, k, block_t=DEFAULT_BLOCK_T):
    """x: (T, N) i32 (values <= 0, post max-subtraction), per-row m, k.

    Bit-exact with intops.di_exp.
    """
    t, n = x.shape
    bt = min(block_t, t)
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        pad = t_pad - t
        x = jnp.pad(x, ((0, pad), (0, 0)))
        m = jnp.pad(m, (0, pad), constant_values=1)
        k = jnp.pad(k, (0, pad))
    out = pl.pallas_call(
        _kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, n), I32),
        interpret=True,
    )(x, m, k)
    return out[:t]
