"""Pure-jnp float oracles for the integer-only kernels.

Every DI-* operator approximates a float computation; these are the float
computations. pytest checks (a) pallas kernel == intops spec bit-exactly,
and (b) intops spec ~= these oracles within the paper's error bounds
(e.g. DI-ClippedSoftmax max error <= c/(2^8-1) ~ 0.059 per element).
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax(x, mask=None):
    x = jnp.asarray(x, jnp.float64)
    if mask is not None:
        x = jnp.where(mask, x, -jnp.inf)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def rmsnorm(x, eps=0.0):
    x = jnp.asarray(x, jnp.float64)
    return x / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def layernorm(x, eps=0.0):
    x = jnp.asarray(x, jnp.float64)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    return xc / jnp.sqrt(jnp.mean(xc * xc, axis=-1, keepdims=True) + eps)


def silu(x):
    x = jnp.asarray(x, jnp.float64)
    return x / (1.0 + jnp.exp(-x))


def swiglu(gate, up, alpha=None):
    """gate * sigmoid(gate / alpha) * up — FSBR's decomposed SiLU.

    alpha: per-channel smoothing factor (None = plain SiLU(gate)*up).
    """
    gate = jnp.asarray(gate, jnp.float64)
    up = jnp.asarray(up, jnp.float64)
    arg = gate if alpha is None else gate / alpha
    return gate * (1.0 / (1.0 + jnp.exp(-arg))) * up


def linear(x, w, b=None):
    y = jnp.matmul(jnp.asarray(x, jnp.float64), jnp.asarray(w, jnp.float64))
    if b is not None:
        y = y + b
    return y


def dequant(vals, m, k, zp):
    """DynQ -> float, per-row dyadic scales."""
    s = m.astype(jnp.float64) / jnp.exp2(k.astype(jnp.float64))
    return (vals.astype(jnp.float64) - zp[..., None]) * s[..., None]


def rope(x, theta=10000.0, pos0=0):
    """Float RoPE on (T, H, D), half-split layout (matches di_rope)."""
    import numpy as np

    t, _, d = x.shape
    half = d // 2
    inv = 1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))
    ang = (np.arange(t, dtype=np.float64) + pos0)[:, None] * inv[None, :]
    c = jnp.asarray(np.cos(ang))[:, None, :]
    s = jnp.asarray(np.sin(ang))[:, None, :]
    x = jnp.asarray(x, jnp.float64)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
