"""Pallas kernel: DI-SwiGLU (paper Alg. 3).

Fuses the FSBR-decomposed gated unit: per-channel de-smooth of the sigmoid
argument (x / alpha via dyadic shift-divide), integer sigmoid built from
two DI-Exp evaluations (sigma(x) = e^{x-M} / (e^{x-M} + e^{-M})), the
three-way product gate * sigma * up, and the dynamic requant epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import intops
from ..intops import I32, I64, fdiv, rdiv

DEFAULT_BLOCK_T = 64


def _kernel(xg_ref, mg_ref, kg_ref, zpg_ref,
            xu_ref, mu_ref, ku_ref, zpu_ref,
            am_ref, ak_ref,
            y_ref, my_ref, ky_ref, zpy_ref, *, p_sig, out_bits):
    gc = (xg_ref[...] - zpg_ref[...][:, None]).astype(I64)
    uc = (xu_ref[...] - zpu_ref[...][:, None]).astype(I64)
    ak = jnp.minimum(ak_ref[...], 24)[None, :].astype(I32)
    xs = fdiv(gc << ak, am_ref[...].astype(I64)[None, :])
    mg = mg_ref[...]
    kg = kg_ref[...]
    # per-element stable integer sigmoid (see intops.di_swiglu)
    zero = jnp.zeros_like(xs)
    e_d = intops.di_exp(jnp.minimum(xs, zero).astype(I32), mg, kg).astype(I64)
    e_m = intops.di_exp(jnp.minimum(-xs, zero).astype(I32), mg, kg).astype(I64)
    psig_max = jnp.asarray(1, I64) << (p_sig - 1)
    sig = rdiv(e_d * psig_max, jnp.maximum(e_d + e_m, 1))
    y = gc * sig * uc
    m_in = mg.astype(I64) * mu_ref[...].astype(I64)
    k_in = kg + ku_ref[...] + (p_sig - 1)
    vals, m_y, k_y, zp = intops.requant_rows(y, m_in, k_in, out_bits)
    y_ref[...] = vals
    my_ref[...] = m_y
    ky_ref[...] = k_y
    zpy_ref[...] = zp


@functools.partial(jax.jit, static_argnames=("p_sig", "out_bits", "block_t"))
def di_swiglu(xg, mg, kg, zpg, xu, mu, ku, zpu, alpha_m, alpha_k,
              p_sig=8, out_bits=8, block_t=DEFAULT_BLOCK_T):
    """Bit-exact with intops.di_swiglu. Shapes: (T, N) + per-row scales +
    per-channel (alpha_m, alpha_k)."""
    t, n = xg.shape
    bt = min(block_t, t)
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        pad = t_pad - t
        pv = lambda a, c=0: jnp.pad(a, (0, pad), constant_values=c)
        xg = jnp.pad(xg, ((0, pad), (0, 0)))
        xu = jnp.pad(xu, ((0, pad), (0, 0)))
        mg, kg, zpg = pv(mg, 1), pv(kg), pv(zpg)
        mu, ku, zpu = pv(mu, 1), pv(ku), pv(zpu)
    kernel = functools.partial(_kernel, p_sig=p_sig, out_bits=out_bits)
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    chan = lambda i: (0,)
    vals, m_y, k_y, zp = pl.pallas_call(
        kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), row), pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec), pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt, n), row), pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec), pl.BlockSpec((bt,), vec),
            pl.BlockSpec((n,), chan), pl.BlockSpec((n,), chan),
        ],
        out_specs=(
            pl.BlockSpec((bt, n), row), pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec), pl.BlockSpec((bt,), vec),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t_pad, n), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
        ),
        interpret=True,
    )(xg, mg, kg, zpg, xu, mu, ku, zpu, alpha_m, alpha_k)
    return vals[:t], m_y[:t], k_y[:t], zp[:t]
