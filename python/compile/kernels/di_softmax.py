"""Pallas kernel: DI-ClippedSoftmax (paper Alg. 2 + Eq. 10).

Row-wise kernel over raw i64 attention scores. Fuses, per row tile:
max-reduce -> clipped floor (Eq. 10, c = cm/2^ck) -> 8-bit window requant
(Eq. 6-8 on the clipped range) -> DI-Exp -> integer normalize (IntDiv).

The clip bounds the quantization window to c regardless of the score
dynamic range, which is what lets an 8-bit softmax input survive the
long-tailed score distributions of LLMs (paper Table 5: c = 15).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import intops
from ..intops import CLIP_K, CLIP_M, I32, I64, K_MAX, rdiv

DEFAULT_BLOCK_T = 64


def _kernel(p_ref, m1_ref, k1_ref, mask_ref, o_ref, *, m2, k2, p_out, cm, ck):
    p = p_ref[...].astype(I64)
    mask = mask_ref[...] != 0
    m_in = m1_ref[...].astype(I64) * jnp.asarray(m2, I64)
    k_in = k1_ref[...] + jnp.asarray(k2, I32)
    p = jnp.where(mask, p, jnp.asarray(-(1 << 62), I64))
    pmax = jnp.max(p, axis=-1)
    sh = jnp.clip(k_in - ck, 0, 56)
    c_i = jnp.maximum((jnp.asarray(cm, I64) << sh) // m_in, 1)
    floor_v = pmax - c_i
    pc = jnp.maximum(p, floor_v[:, None])
    rng = jnp.maximum(pmax - floor_v, 1)
    qmax = jnp.asarray(255, I64)
    x8 = rdiv((pc - floor_v[:, None]) * qmax, rng[:, None]).astype(I32)
    num = qmax << jnp.minimum(k_in + 8, 56).astype(I32)
    k8 = jnp.clip(
        intops.ilog2(jnp.maximum(num // (rng * m_in), 1)).astype(I32), 0, K_MAX
    )
    sh8 = k8 - k_in
    prod = rng * m_in
    m8 = jnp.where(sh8 >= 0, (prod << jnp.maximum(sh8, 0)) // qmax,
                   (prod >> jnp.maximum(-sh8, 0)) // qmax)
    m8 = jnp.clip(m8, 1, 255).astype(I32)
    e = intops.di_exp(x8 - 255, m8, k8).astype(I64)
    e = jnp.where(mask, e, 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1), 1)
    pout_max = jnp.asarray(1, I64) << (p_out - 1)
    o_ref[...] = rdiv(e * pout_max, denom[:, None]).astype(I32)


@functools.partial(jax.jit, static_argnames=("m2", "k2", "p_out", "clip",
                                             "block_t"))
def di_clipped_softmax(p, m1, k1, mask, m2, k2, p_out=8,
                       clip=(CLIP_M, CLIP_K), block_t=DEFAULT_BLOCK_T):
    """p: (T, S) i64 scores, per-row (m1, k1); key-side scalars (m2, k2).

    mask: (T, S) i32/bool, nonzero = attend. Bit-exact with
    intops.di_clipped_softmax.
    """
    t, s = p.shape
    bt = min(block_t, t)
    t_pad = (t + bt - 1) // bt * bt
    mask = mask.astype(I32)
    if t_pad != t:
        pad = t_pad - t
        p = jnp.pad(p, ((0, pad), (0, 0)))
        m1 = jnp.pad(m1, (0, pad), constant_values=1)
        k1 = jnp.pad(k1, (0, pad))
        mask = jnp.pad(mask, ((0, pad), (0, 0)), constant_values=1)
    cm, ck = clip
    kernel = functools.partial(
        _kernel, m2=int(m2), k2=int(k2), p_out=p_out, cm=cm, ck=ck
    )
    out = pl.pallas_call(
        kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, s), I32),
        interpret=True,
    )(p, m1, k1, mask)
    return out[:t]
