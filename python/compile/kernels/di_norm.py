"""Pallas kernel: DI-Norm (paper Alg. 4) — integer RMSNorm / LayerNorm.

Row-wise kernel: center (LayerNorm only), i64 sum-of-squares, bit-wise
I-SQRT (the paper's non-restoring square root — consistent between
calibration and inference, unlike I-BERT's Newton iterations), Q16
normalize, then the standard dynamic requant epilogue.

gamma/beta are folded into the following linear offline (FSBR's serial
norm-linear smoothing already rewrites them), so the kernel is pure
normalization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import intops
from ..intops import I32, I64, NORM_FP_K, fdiv, rdiv

DEFAULT_BLOCK_T = 64


def _kernel(x_ref, zp_ref, y_ref, my_ref, ky_ref, zpy_ref, *,
            centered, p_out):
    x = x_ref[...]
    zp = zp_ref[...]
    xc = (x - zp[:, None]).astype(I64)
    n = x.shape[-1]
    if centered:
        mu = rdiv(jnp.sum(xc, axis=-1), jnp.asarray(n, I64))
        xc = xc - mu[:, None]
    var = jnp.sum(xc * xc, axis=-1)
    std = jnp.maximum(intops.isqrt(var), 1)
    dsq = intops.isqrt(jnp.asarray(n, I64) << 20)
    num = xc * dsq * (jnp.asarray(1, I64) << 6)
    y = fdiv(num, std[:, None])
    bt = x.shape[0]
    m_in = jnp.ones((bt,), I64)
    k_in = jnp.full((bt,), NORM_FP_K, I32)
    vals, m_y, k_y, zpy = intops.requant_rows(y, m_in, k_in, p_out)
    y_ref[...] = vals
    my_ref[...] = m_y
    ky_ref[...] = k_y
    zpy_ref[...] = zpy


@functools.partial(jax.jit, static_argnames=("centered", "p_out", "block_t"))
def di_norm(x, zpx, centered=False, p_out=8, block_t=DEFAULT_BLOCK_T):
    """x: (T, N) i32 DynQ values, per-row zp (scale cancels in x/rms).

    centered=True -> LayerNorm, False -> RMSNorm.
    Bit-exact with intops.di_norm.
    """
    t, n = x.shape
    bt = min(block_t, t)
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        pad = t_pad - t
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1)
        zpx = jnp.pad(zpx, (0, pad))
    kernel = functools.partial(_kernel, centered=centered, p_out=p_out)
    vals, m_y, k_y, zp = pl.pallas_call(
        kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t_pad, n), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
            jax.ShapeDtypeStruct((t_pad,), I32),
        ),
        interpret=True,
    )(x, zpx)
    return vals[:t], m_y[:t], k_y[:t], zp[:t]
