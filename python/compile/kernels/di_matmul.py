"""Pallas kernel: fused DI-MatMul (integer GEMM + dynamic requant epilogue).

This is the paper's compute hot-spot (Eq. 2-8). The kernel fuses, per
token-tile:

  1. zero-point-centered i32 GEMM         P = (X - zp) @ Wq
  2. per-channel mantissa fold            P *= mw[None, :]      (i64)
  3. dynamic range reduction              pmax/pmin over the row
  4. dyadic output-scale solve (Eq. 6-7)  k_y via MSB, m_y by shift
  5. requantization (Eq. 8)               round-half-up to out_bits

TPU mapping (DESIGN.md §Hardware-Adaptation): the GEMM runs on the MXU as
an i8xi8->i32 contraction per (BT, K)x(K, N) tile held in VMEM; steps 2-5
are VPU element-wise/reduction work fused into the same kernel so P never
round-trips to HBM. Zero-point cross terms are avoided entirely by
centering X in VMEM (weights are symmetric, zp_w = 0).

interpret=True everywhere in this repo: CPU PJRT cannot execute Mosaic
custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import intops
from ..intops import ACT_K_MAX, I32, I64

DEFAULT_BLOCK_T = 64


def _requant_epilogue(p, m_in, k_in, qmax_i):
    """Steps 3-5 on an in-VMEM (BT, N) i64 tile. Mirrors intops.requant_rows."""
    qmax = jnp.asarray(qmax_i, I64)
    pmax = jnp.maximum(jnp.max(p, axis=-1), 0)
    pmin = jnp.minimum(jnp.min(p, axis=-1), 0)
    rng = jnp.maximum(pmax - pmin, 1)
    num = qmax << jnp.minimum(k_in + 8, 56).astype(I32)
    k_y = jnp.clip(
        intops.ilog2(jnp.maximum(num // (rng * m_in), 1)).astype(I32), 0,
        ACT_K_MAX,
    )
    sh = k_y - k_in
    prod = rng * m_in
    m_y = jnp.where(
        sh >= 0,
        (prod << jnp.maximum(sh, 0)) // qmax,
        (prod >> jnp.maximum(-sh, 0)) // qmax,
    )
    m_y = jnp.clip(m_y, 1, 255).astype(I32)
    zp = intops.rdiv(-pmin * qmax, rng).astype(I32)
    vals = intops.rdiv((p - pmin[..., None]) * qmax, rng[..., None]).astype(I32)
    return vals, m_y, k_y, zp


def _kernel(x_ref, mx_ref, kx_ref, zpx_ref, w_ref, mw_ref,
            y_ref, my_ref, ky_ref, zpy_ref, *, out_bits):
    xc = x_ref[...] - zpx_ref[...][:, None]
    p = jnp.matmul(xc, w_ref[...], preferred_element_type=I32).astype(I64)
    p = p * mw_ref[...][None, :].astype(I64)
    m_in = mx_ref[...].astype(I64)
    k_in = kx_ref[...] + jnp.asarray(0, I32)  # kw folded by caller
    vals, m_y, k_y, zp = _requant_epilogue(p, m_in, k_in, (1 << out_bits) - 1)
    y_ref[...] = vals
    my_ref[...] = m_y
    ky_ref[...] = k_y
    zpy_ref[...] = zp


@functools.partial(jax.jit, static_argnames=("out_bits", "block_t"))
def di_matmul(x, mx, kx, zpx, wq, mw, kw, out_bits=8,
              block_t=DEFAULT_BLOCK_T):
    """Fused dynamic integer-only linear: returns (vals, m, k, zp).

    x (T, K) i32, per-row (mx, kx, zpx); wq (K, N) i32 symmetric weights
    with per-channel mantissas mw (N,) at common exponent kw (python int
    or traced scalar folded into kx here).
    Bit-exact with intops.di_linear(..., bias_i=None).
    """
    t, _ = x.shape
    n = wq.shape[1]
    bt = min(block_t, t)
    # pad T to a multiple of bt (extra rows quantize independently; sliced off)
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        pad = t_pad - t
        x = jnp.pad(x, ((0, pad), (0, 0)))
        mx = jnp.pad(mx, (0, pad), constant_values=1)
        kx = jnp.pad(kx, (0, pad))
        zpx = jnp.pad(zpx, (0, pad))
    kx_eff = kx + jnp.asarray(kw, I32)

    grid = (t_pad // bt,)
    kernel = functools.partial(_kernel, out_bits=out_bits)
    out_shapes = (
        jax.ShapeDtypeStruct((t_pad, n), I32),
        jax.ShapeDtypeStruct((t_pad,), I32),
        jax.ShapeDtypeStruct((t_pad,), I32),
        jax.ShapeDtypeStruct((t_pad,), I32),
    )
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    vals, m_y, k_y, zp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, x.shape[1]), row),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((wq.shape[0], n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((bt, n), row),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(x, mx, kx_eff, zpx, wq, mw)
    return vals[:t], m_y[:t], k_y[:t], zp[:t]
