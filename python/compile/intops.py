"""Integer-only operator specification (pure jnp).

This module is the *specification* of every dynamic integer-only (DI)
operator in the I-LLM paper, shared by three consumers:

  1. the L2 JAX integer model (`model.py`) — lowered to HLO and executed
     from rust via PJRT,
  2. the L1 Pallas kernels (`kernels/*.py`) — checked against these
     functions in pytest,
  3. the L3 rust `ops/` crate — a bit-exact native mirror, cross-checked
     through golden vectors (`aot.py --goldens`) and through the
     native-vs-PJRT integration test.

Bit-exactness rules (rust must follow the same):
  * all divisions are FLOOR divisions (numpy `//` semantics, also for
    negative operands); rust uses an explicit `fdiv` helper,
  * "round" is implemented as `floor_div(num + den // 2, den)` —
    round-half-up, never banker's rounding,
  * right shifts on negative ints are arithmetic (floor) shifts,
  * accumulation in int32 where the bound allows it, int64 for
    requantization arithmetic and residual alignment.

Quantized activation layout ("DynQ"): integer values in [0, 2^bits),
plus per-row (per-token) dyadic scale s = m / 2^k and zero point zp.
Weights are per-output-channel symmetric with mantissas aligned to one
common exponent k_w (see `align_channel_scales`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# int64 is required for requantization arithmetic (products up to ~2^56).
# Explicit dtypes are used everywhere, so enabling x64 does not change the
# behaviour of f32 model code.
jax.config.update("jax_enable_x64", True)

I32 = jnp.int32
I64 = jnp.int64

# Bound on dyadic exponents so (qmax << (k + 8)) stays in i64.
K_MAX = 46
# Activation-scale exponent cap: composite exponents (k_gate + k_up +
# p_sig - 1, k_act + k_w + 8, ...) must stay <= 55 for i64 shifts.
ACT_K_MAX = 20
# Weight common-exponent cap.
W_K_MAX = 24


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def fdiv(a, b):
    """Floor division (numpy // semantics). a, b integer arrays."""
    return a // b


def rdiv(a, b):
    """Round-half-up division for b > 0: floor((a + b//2) / b)."""
    return (a + b // 2) // b


def round_half_away(x):
    """Round float values half AWAY from zero (offline value rounding).

    jnp.floor(x + 0.5) rounds negative halves toward +inf (-1.5 -> -1),
    which biases symmetric weight quantization upward; rounding the
    magnitude keeps q(-x) == -q(x). Mirrors rust quant::round_half_away.
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def ilog2(x):
    """floor(log2(x)) for x >= 1, via bit counting (MSB method, Eq. 6)."""
    x = jnp.asarray(x, I64)
    r = jnp.zeros_like(x)
    for shift in (32, 16, 8, 4, 2, 1):
        hit = x >= (jnp.asarray(1, I64) << shift)
        r = jnp.where(hit, r + shift, r)
        x = jnp.where(hit, x >> shift, x)
    return r


def isqrt(x):
    """Bit-wise integer square root of int64 x >= 0 (paper Alg. 4 I-SQRT).

    Non-restoring method: the largest n with n*n <= x.
    """
    x = jnp.asarray(x, I64)
    n = jnp.zeros_like(x)
    rem = x
    # 31 bit-pairs cover int64 inputs up to 2^62.
    for v in range(30, -1, -1):
        bit = jnp.asarray(1, I64) << v
        # (n + 2^v)^2 - n^2 = (2n + 2^v) * 2^v
        temp = ((n << 1) + bit) << v
        take = rem >= temp
        rem = jnp.where(take, rem - temp, rem)
        n = jnp.where(take, n + bit, n)
    return n


def quantize_f32(x, bits):
    """Float -> (vals, m, k, zp) asymmetric per-row quantization.

    Offline/boundary only (weights, embedding table, goldens). Runtime
    requantization never touches floats — see `requant_rows`.
    x: (..., N) float; scales per leading rows.
    """
    qmax = (1 << bits) - 1
    # include zero in the range: keeps zp in [0, qmax] (representable)
    # and makes constant rows exact — standard asymmetric-quant practice.
    xmax = jnp.maximum(jnp.max(x, axis=-1), 0.0)
    xmin = jnp.minimum(jnp.min(x, axis=-1), 0.0)
    rng = jnp.maximum(xmax - xmin, 1e-9)
    s = rng / qmax
    m, k = dyadic_from_float(s)
    s_d = m.astype(jnp.float64) / (jnp.asarray(1, I64) << k).astype(jnp.float64)
    zp = jnp.clip(jnp.floor(-xmin / s_d + 0.5), 0, qmax).astype(I32)
    vals = jnp.clip(
        round_half_away(x / s_d[..., None]).astype(I64)
        + zp[..., None].astype(I64),
        0,
        qmax,
    ).astype(I32)
    return vals, m, k, zp


def dyadic_from_float(s):
    """Float scale -> dyadic (m, k) with m in [128, 255] (normalized).

    Offline only. k = floor(log2(255 / s)); m = round(s * 2^k).
    """
    s = jnp.asarray(s, jnp.float64)
    k = jnp.floor(jnp.log2(255.0 / s)).astype(I32)
    k = jnp.clip(k, 0, ACT_K_MAX)
    m = jnp.floor(s * jnp.exp2(k.astype(jnp.float64)) + 0.5).astype(I32)
    # m could land on 256 by rounding; renormalize.
    bump = m > 255
    m = jnp.where(bump, (m + 1) >> 1, m)
    k = jnp.where(bump, k - 1, k)
    return jnp.maximum(m, 1), k


def dyadic_to_float(m, k):
    return m.astype(jnp.float64) / jnp.exp2(k.astype(jnp.float64))


def align_channel_scales(s, k_common_bits=14):
    """Per-channel float scales -> integer mantissas at one common exponent.

    Returns (mw: i32 per channel, kw: scalar i32) with s[c] ~= mw[c] / 2^kw
    and max mantissa < 2^15 (so P * mw fits i64 after i32 accumulation).
    """
    s = jnp.asarray(s, jnp.float64)
    smax = jnp.max(s)
    # largest kw with round(smax * 2^kw) < 2^15
    kw = jnp.clip(
        jnp.floor(jnp.log2((1 << 14) / smax)).astype(I32), 0, W_K_MAX
    )
    mw = jnp.floor(s * jnp.exp2(kw.astype(jnp.float64)) + 0.5).astype(I32)
    return jnp.maximum(mw, 1), kw


# ---------------------------------------------------------------------------
# requantization (Eq. 4-8) — the heart of DI-MatMul
# ---------------------------------------------------------------------------

def requant_rows(p, m_in, k_in, bits, clip=None):
    """Dynamically requantize integer rows to `bits` (Eq. 6-8).

    p:    (T, N) int64 raw values with conceptual scale m_in / 2^k_in
    m_in: (T,) int64 per-row mantissa;  k_in: (T,) int32 per-row exponent
    clip: optional (cm, ck) dyadic clip constant c = cm / 2^ck in OUTPUT
          float units: limits p_min >= p_max - c / s_in (Eq. 10).
    Returns (vals i32 in [0, qmax], m_y i32, k_y i32, zp i32) per row.
    """
    p = jnp.asarray(p, I64)
    m_in = jnp.asarray(m_in, I64)
    k_in = jnp.asarray(k_in, I32)
    qmax = jnp.asarray((1 << bits) - 1, I64)

    # include zero in the range (see quantize_f32)
    pmax = jnp.maximum(jnp.max(p, axis=-1), 0)
    pmin = jnp.minimum(jnp.min(p, axis=-1), 0)
    if clip is not None:
        cm, ck = clip
        # c^I = (cm << (k_in - ck)) / m_in: clip constant in p-units
        # (p_float = p * m_in / 2^k_in, so c/s_in = c * 2^k_in / m_in).
        sh = jnp.clip(k_in - ck, 0, 56)
        c_i = fdiv(jnp.asarray(cm, I64) << sh, m_in)
        pmin = jnp.maximum(pmin, pmax - jnp.maximum(c_i, 1))
        p = jnp.maximum(p, pmin[..., None])
    rng = jnp.maximum(pmax - pmin, 1)

    # Eq. 6 (with the mantissa kept, m_y normalized into [128, 255]):
    #   k_y = floor(log2(qmax * 2^(k_in+8) / (rng * m_in)))
    num = qmax << jnp.minimum(k_in + 8, 56).astype(I32)
    k_y = ilog2(jnp.maximum(num // (rng * m_in), 1)).astype(I32)
    k_y = jnp.clip(k_y, 0, ACT_K_MAX)
    # Eq. 7: m_y = floor(rng * m_in * 2^(k_y - k_in) / qmax)
    sh = k_y - k_in
    prod = rng * m_in
    m_y = jnp.where(
        sh >= 0,
        (prod << jnp.maximum(sh, 0)) // qmax,
        (prod >> jnp.maximum(-sh, 0)) // qmax,
    )
    m_y = jnp.clip(m_y, 1, 255).astype(I32)
    # Eq. 8 (round-half-up):
    zp = rdiv(-pmin * qmax, rng).astype(I32)
    vals = rdiv((p - pmin[..., None]) * qmax, rng[..., None]) .astype(I32)
    return vals, m_y, k_y, zp


def requant_common(x, mx, kx, zpx, bits):
    """Requantize per-row-scaled DynQ rows to ONE shared dyadic scale.

    Used for the key/value blocks of attention: Q keeps per-token scales,
    K/V are requantized per head to a single (m, k, zp) so that the score
    matrix has one scale per query row (required by the integer max in
    DI-ClippedSoftmax). All-integer: rows are aligned to the max exponent
    then jointly range-reduced.
    Returns (vals (T,N) i32, m i32, k i32, zp i32) — scalar scales.
    """
    xc = (x - zpx[..., None]).astype(I64)
    kc = jnp.max(kx)
    sh = jnp.minimum(kc - kx, 32).astype(I32)
    v = xc * (mx.astype(I64) << sh)[..., None]
    flat = v.reshape(1, -1)
    vals, m, k, zp = requant_rows(
        flat, jnp.ones((1,), I64), jnp.full((1,), kc, I32), bits
    )
    return vals.reshape(x.shape), m[0], k[0], zp[0]


def requant_per_head(x3, mx, kx, zpx, bits):
    """Vectorized `requant_common` over the head axis.

    x3: (T, H, D) i32 values with per-token scales (mx, kx, zpx); zpx may
    be None when x3 is already centered (post-RoPE). Each head's (T, D)
    block is requantized to ONE shared dyadic scale.
    Returns (vals (H, T, D) i64 CENTERED, m (H,), k (H,), zp (H,)).
    """
    t, h, d = x3.shape
    xc = x3.astype(I64) if zpx is None else (
        x3 - zpx[:, None, None]).astype(I64)
    kcom = jnp.max(kx)
    sh = jnp.minimum(kcom - kx, 32).astype(I32)
    v = xc * (mx.astype(I64) << sh)[:, None, None]
    flat = jnp.transpose(v, (1, 0, 2)).reshape(h, t * d)
    vals, m, k, zp = requant_rows(
        flat, jnp.ones((h,), I64), jnp.full((h,), kcom, I32), bits)
    cent = (vals.reshape(h, t, d) - zp[:, None, None]).astype(I64)
    return cent, m, k, zp


# ---------------------------------------------------------------------------
# DI-MatMul (Eq. 2-8)
# ---------------------------------------------------------------------------

BIAS_Q = 16  # fixed-point exponent of offline-quantized biases


def di_linear_raw(x, mx, kx, zpx, wq, mw, kw, bias_q):
    """DI-MatMul accumulate phase: returns raw (p i64, m_in i64, k_in i32).

    x:  (T, K) i32 quantized activations, per-row (mx, kx, zpx)
    wq: (K, N) i32 symmetric per-channel weights (values in [-127,127])
    mw: (N,) i32 channel mantissas at common exponent kw (i32 scalar)
    bias_q: optional (N,) i64 bias in Q(BIAS_Q) fixed point,
            bias_q[n] = round(b[n] * 2^BIAS_Q). Aligned to P's per-row
            scale via p += fdiv(bias_q << (k_in - BIAS_Q), m_in) —
            all-integer (Eq. 3 extended with a bias term).
    """
    xc = (x - zpx[..., None]).astype(I32)
    p = jnp.matmul(xc, wq, preferred_element_type=I32).astype(I64)
    p = p * mw[None, :].astype(I64)  # fold per-channel mantissa
    m_in = mx.astype(I64)
    k_in = (kx + kw).astype(I32)
    if bias_q is not None:
        sh = jnp.clip(k_in - BIAS_Q, -40, 40)[..., None]
        num = jnp.where(
            sh >= 0,
            bias_q[None, :] << jnp.maximum(sh, 0),
            bias_q[None, :] >> jnp.maximum(-sh, 0),
        )
        p = p + fdiv(num, m_in[..., None])
    return p, m_in, k_in


def di_linear(x, mx, kx, zpx, wq, mw, kw, bias_q, out_bits):
    """Dynamic integer-only linear layer (Eq. 2-8): accumulate + requant."""
    p, m_in, k_in = di_linear_raw(x, mx, kx, zpx, wq, mw, kw, bias_q)
    return requant_rows(p, m_in, k_in, out_bits)


def bias_quantize(b):
    """Offline: float bias -> i64 Q(BIAS_Q) fixed point."""
    return jnp.floor(
        jnp.asarray(b, jnp.float64) * (1 << BIAS_Q) + 0.5
    ).astype(I64)


# ---------------------------------------------------------------------------
# DI-Exp (Alg. 1)
# ---------------------------------------------------------------------------

def di_exp(x, m, k):
    """Shift-only exponential. x: i32 <= 0 values (post max-subtraction)
    with scale m/2^k (per-row m, k broadcast over last dim).
    Returns i32 'unshifted' exponential with conceptual scale s_f = 1/t
    (the caller only ever uses ratios, so s_f cancels).
    """
    x = jnp.asarray(x, I64)
    m = jnp.asarray(m, I64)[..., None]
    k = jnp.asarray(k, I32)[..., None]
    m_f = m + (m >> 1) - (m >> 4)  # ~ m * log2(e)
    # t = round(-1 / s_f) with s_f = m_f / 2^k  ->  t = -round(2^k / m_f)
    two_k = jnp.asarray(1, I64) << jnp.minimum(k, 62).astype(I32)
    t = -jnp.maximum(rdiv(two_k, m_f), 1)
    q = fdiv(x, t)  # >= 0 since x <= 0, t < 0
    r = x - q * t  # in (t, 0]
    unshifted = (r >> 1) - t  # ~ (1 - |r|/(2|t|)) * |t|
    qc = jnp.minimum(q, 62)
    return (unshifted >> qc.astype(I32)).astype(I32)


# ---------------------------------------------------------------------------
# DI-ClippedSoftmax (Alg. 2 + Eq. 10)
# ---------------------------------------------------------------------------

# clip constant c = 15 as dyadic 240/2^4 (paper Table 5 optimum).
CLIP_M, CLIP_K = 240, 4


def di_clipped_softmax(p, m1, k1, m2, k2, p_out, mask=None,
                       clip=(CLIP_M, CLIP_K)):
    """Softmax over raw i64 attention scores P (per-row scale m1*m2/2^(k1+k2)).

    p: (T, S) int64; m1,k1 per-row (query token); m2,k2 scalar or
    per-row (key-side shared scale, one per row's head).
    mask: optional (T, S) bool, True = attend. Masked entries excluded
    from the max and forced to probability 0.
    Returns (y i32 in [0, 2^(p_out-1)], m_out=1, k_out=p_out-1).
    """
    p = jnp.asarray(p, I64)
    m_in = (jnp.asarray(m1, I64) * jnp.asarray(m2, I64))
    k_in = jnp.asarray(k1, I32) + jnp.asarray(k2, I32)
    if mask is not None:
        very_small = jnp.asarray(-(1 << 62), I64)
        p = jnp.where(mask, p, very_small)
    # max over valid entries
    pmax = jnp.max(p, axis=-1)
    # clipped floor (Eq. 10): p_min >= p_max - c^I with
    # c^I = (cm << (k_in - ck)) / m_in  (clip constant in p-units)
    cm, ck = clip
    sh = jnp.clip(k_in - ck, 0, 56)
    c_i = jnp.maximum(fdiv(jnp.asarray(cm, I64) << sh, m_in), 1)
    floor_v = pmax - c_i
    pc = jnp.maximum(p, floor_v[..., None])
    rng = jnp.maximum(pmax - floor_v, 1)
    qmax = jnp.asarray(255, I64)
    # 8-bit row requant of the clipped window (scale = rng*m_in/(255*2^k_in))
    x8 = rdiv((pc - floor_v[..., None]) * qmax, rng[..., None]).astype(I32)
    num = qmax << jnp.minimum(k_in + 8, 56).astype(I32)
    k8 = jnp.clip(ilog2(jnp.maximum(num // (rng * m_in), 1)).astype(I32), 0, K_MAX)
    sh8 = k8 - k_in
    prod = rng * m_in
    m8 = jnp.where(sh8 >= 0, (prod << jnp.maximum(sh8, 0)) // qmax,
                   (prod >> jnp.maximum(-sh8, 0)) // qmax)
    m8 = jnp.clip(m8, 1, 255).astype(I32)
    # exp of (x8 - 255) at scale m8/2^k8
    e = di_exp(x8 - 255, m8, k8).astype(I64)
    if mask is not None:
        e = jnp.where(mask, e, 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1), 1)
    pout_max = jnp.asarray(1, I64) << (p_out - 1)
    y = rdiv(e * pout_max, denom[..., None]).astype(I32)
    return y  # scale = 1 / 2^(p_out-1), zp = 0


# ---------------------------------------------------------------------------
# DI-Norm (Alg. 4) — RMSNorm and LayerNorm, gamma folded into next linear
# ---------------------------------------------------------------------------

NORM_FP_K = 16  # output fixed-point exponent before requant


def di_norm(x, zpx, p_out, centered):
    """Integer-only normalization of (T, N) i32 rows.

    x quantized per-row; the row scale CANCELS in x/rms(x), so only the
    centered integers matter. gamma/beta are folded into the following
    linear (weights were pre-multiplied offline), making this pure
    normalization: y = xc * sqrt(N) / sqrt(sum(xc^2))  [RMSNorm]
    or the mean-subtracted variant [LayerNorm].
    Output: DynQ at p_out bits (per-row dynamic requant of Q16 values).
    """
    xc = (x - zpx[..., None]).astype(I64)
    n = x.shape[-1]
    if centered:
        mu = rdiv(jnp.sum(xc, axis=-1), jnp.asarray(n, I64))
        xc = xc - mu[..., None]
    var = jnp.sum(xc * xc, axis=-1)
    std = jnp.maximum(isqrt(var), 1)  # = sqrt(sum xc^2)
    dsq = isqrt(jnp.asarray(n, I64) << 20)  # sqrt(N) in Q10
    # y_q16 = xc * sqrt(N) * 2^16 / std   (Q16 fixed point, |y| <~ 12)
    num = xc * dsq * (jnp.asarray(1, I64) << 6)
    y = fdiv(num, std[..., None])
    t = x.shape[0]
    m_in = jnp.ones((t,), I64)
    k_in = jnp.full((t,), NORM_FP_K, I32)
    return requant_rows(y, m_in, k_in, p_out)


# ---------------------------------------------------------------------------
# DI-SwiGLU (Alg. 3)
# ---------------------------------------------------------------------------

def di_swiglu(xg, mg, kg, zpg, xu, mu, ku, zpu, alpha_m, alpha_k,
              p_sig, out_bits):
    """Integer-only SwiGLU: y = gate * sigmoid(gate / alpha) * up.

    xg/xu: (T, N) i32 quantized gate/up activations with per-row scales.
    alpha_m/alpha_k: (N,) i32 per-channel dyadic act-smooth factors
    (FSBR's s; sigma'(x) = sigma(x / s)). Pass ones/zeros for identity.
    p_sig: sigmoid probability bits (8). Output requantized to out_bits.
    """
    gc = (xg - zpg[..., None]).astype(I64)
    uc = (xu - zpu[..., None]).astype(I64)
    # de-smooth the sigmoid argument: x / alpha = x * 2^alpha_k / alpha_m
    xs = fdiv(gc << jnp.minimum(alpha_k, 24)[None, :].astype(I32),
              jnp.asarray(alpha_m, I64)[None, :])
    # Per-ELEMENT stable integer sigmoid:
    #   sigma(x) = e^{min(x,0)} / (e^{min(x,0)} + e^{min(-x,0)})
    # (both DI-Exp arguments <= 0). The paper's Alg. 3 subtracts the ROW
    # max instead, which underflows both exponentials to 0 for rows with
    # wide dynamic range — the per-element form is exact for any range.
    # Documented as an Alg-3 fix in DESIGN.md.
    zero = jnp.zeros_like(xs)
    e_d = di_exp(jnp.minimum(xs, zero).astype(I32), mg, kg).astype(I64)
    e_m = di_exp(jnp.minimum(-xs, zero).astype(I32), mg, kg).astype(I64)
    psig_max = jnp.asarray(1, I64) << (p_sig - 1)
    sig = rdiv(e_d * psig_max, jnp.maximum(e_d + e_m, 1))
    y = gc * sig * uc  # scale = sg * su / 2^(p_sig-1)
    m_in = mg.astype(I64) * mu.astype(I64)
    k_in = kg + ku + (p_sig - 1)
    return requant_rows(y, m_in, k_in, out_bits)


# ---------------------------------------------------------------------------
# integer residual add
# ---------------------------------------------------------------------------

def di_add(xa, ma, ka, zpa, xb, mb, kb, zpb, out_bits):
    """Residual add of two DynQ tensors -> DynQ at out_bits.

    Aligns both to the max exponent (capped shift 32) and requantizes.
    """
    ac = (xa - zpa[..., None]).astype(I64)
    bc = (xb - zpb[..., None]).astype(I64)
    kc = jnp.maximum(ka, kb)
    sa = jnp.minimum(kc - ka, 32).astype(I32)
    sb = jnp.minimum(kc - kb, 32).astype(I32)
    y = (ac * (ma.astype(I64) << sa)[..., None]
         + bc * (mb.astype(I64) << sb)[..., None])
    m_in = jnp.ones_like(ma, I64)
    return requant_rows(y, m_in, kc, out_bits)


# ---------------------------------------------------------------------------
# integer RoPE (precomputed Q14 tables — constants, no runtime FP)
# ---------------------------------------------------------------------------

ROPE_Q = 14


def rope_tables(head_dim, max_seq, theta=10000.0):
    """Offline: integer Q14 cos/sin tables, shape (max_seq, head_dim/2)."""
    import numpy as np

    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))
    ang = np.arange(max_seq, dtype=np.float64)[:, None] * inv[None, :]
    cos_q = np.floor(np.cos(ang) * (1 << ROPE_Q) + 0.5).astype(np.int32)
    sin_q = np.floor(np.sin(ang) * (1 << ROPE_Q) + 0.5).astype(np.int32)
    return cos_q, sin_q


def di_rope(x, zpx, cos_q, sin_q):
    """Apply integer RoPE to (T, H, D) centered-on-the-fly values.

    x i32 quantized (per-row scales unchanged by rotation). cos_q/sin_q:
    (T, D/2) Q14 tables for the row positions. Returns centered i32
    values (zp removed), same scale as input.
    """
    xc = (x - zpx[:, None, None]).astype(I64)
    d = x.shape[-1]
    h = d // 2
    x1, x2 = xc[..., :h], xc[..., h:]
    c = cos_q[:, None, :].astype(I64)
    s = sin_q[:, None, :].astype(I64)
    half = jnp.asarray(1 << (ROPE_Q - 1), I64)
    r1 = (x1 * c - x2 * s + half) >> ROPE_Q
    r2 = (x1 * s + x2 * c + half) >> ROPE_Q
    return jnp.concatenate([r1, r2], axis=-1).astype(I32)


# ---------------------------------------------------------------------------
# integer ReLU (OPT-style MLP)
# ---------------------------------------------------------------------------

def di_relu(x, zpx):
    """ReLU on DynQ values: max(x, zp). Scale/zp unchanged."""
    return jnp.maximum(x, zpx[..., None])
