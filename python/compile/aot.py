"""AOT compile path: lower L2 models (+ one L1 pallas kernel) to HLO text.

HLO *text* is the interchange format — jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). NEVER use
`lowered.compile().serialize()` here.

Outputs (under artifacts/):
  manifest.json                 — everything the rust runtime needs
  <model>.weights.bin           — FP weights (written by train.py)
  fp_forward.<model>.s<T>.hlo.txt
  int_forward.<model>.<scheme>.s<T>.hlo.txt
  kernels/di_matmul.hlo.txt     — the L1 pallas kernel, standalone
  goldens.json                  — cross-language op test vectors

Usage: python -m compile.aot --out ../artifacts [--steps N] [--fast]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import intops, train
from .intops import I32, I64
from .model import (ModelConfig, PRESETS, QuantScheme, fp_forward,
                    fp_param_spec, int_forward, int_param_spec,
                    int_params_from_fp)

SEQ_BUCKETS = (64, 256)
SCHEMES = {"w8a8": QuantScheme(8, 8), "w4a4": QuantScheme(4, 4),
           "w6a6": QuantScheme(6, 6)}
DTYPES = {"i32": jnp.int32, "i64": jnp.int64, "f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # literals as `constant({...})`, which the 0.5.1-era text parser
    # accepts silently and materializes as garbage — causal masks and
    # RoPE tables would vanish from the artifact.
    return comp.as_hlo_text(True)


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# model lowering
# ---------------------------------------------------------------------------

def lower_fp_forward(cfg: ModelConfig, seq: int) -> tuple[str, list]:
    spec = fp_param_spec(cfg)

    def fn(tokens, *flat):
        params = {name: arr for (name, _), arr in zip(spec, flat)}
        return (fp_forward(cfg, params, tokens),)

    args = [jax.ShapeDtypeStruct((seq,), jnp.int32)]
    args += [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in spec]
    lowered = jax.jit(fn).lower(*args)
    params_meta = [{"name": n, "shape": list(s), "dtype": "f32"}
                   for n, s in spec]
    return to_hlo_text(lowered), params_meta


def lower_int_forward(cfg: ModelConfig, scheme: QuantScheme,
                      seq: int) -> tuple[str, list]:
    spec = int_param_spec(cfg)

    def fn(tokens, *flat):
        qp = {name: arr for (name, _, _), arr in zip(spec, flat)}
        return (int_forward(cfg, qp, tokens, scheme),)

    args = [jax.ShapeDtypeStruct((seq,), jnp.int32)]
    args += [jax.ShapeDtypeStruct(shape, DTYPES[dt]) for _, shape, dt in spec]
    lowered = jax.jit(fn).lower(*args)
    params_meta = [{"name": n, "shape": list(s), "dtype": dt}
                   for n, s, dt in spec]
    return to_hlo_text(lowered), params_meta


def lower_di_matmul_kernel(t=64, k=128, n=128, out_bits=8) -> str:
    """Standalone L1 pallas kernel artifact (proves pallas->HLO->rust)."""
    from .kernels.di_matmul import di_matmul

    def fn(x, mx, kx, zpx, wq, mw):
        return (di_matmul(x, mx, kx, zpx, wq, mw, 12, out_bits),)

    args = [
        jax.ShapeDtypeStruct((t, k), I32),
        jax.ShapeDtypeStruct((t,), I32),
        jax.ShapeDtypeStruct((t,), I32),
        jax.ShapeDtypeStruct((t,), I32),
        jax.ShapeDtypeStruct((k, n), I32),
        jax.ShapeDtypeStruct((n,), I32),
    ]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# golden vectors for the rust ops crate
# ---------------------------------------------------------------------------

def make_goldens(seed: int = 42) -> dict:
    rng = np.random.default_rng(seed)
    g = {}
    # ilog2 / isqrt
    xs = np.concatenate([
        np.arange(1, 40), 2 ** np.arange(0, 60, dtype=np.int64),
        rng.integers(1, 1 << 60, 50),
    ]).astype(np.int64)
    g["ilog2"] = {"x": xs.tolist(),
                  "y": np.asarray(intops.ilog2(jnp.asarray(xs))).tolist()}
    sq = np.concatenate([np.arange(0, 40),
                         rng.integers(0, 1 << 60, 50)]).astype(np.int64)
    g["isqrt"] = {"x": sq.tolist(),
                  "y": np.asarray(intops.isqrt(jnp.asarray(sq))).tolist()}
    # dyadic_from_float
    sc = np.concatenate([10.0 ** rng.uniform(-7, 2, 40), [1.0, 0.5, 255.0]])
    m, k = intops.dyadic_from_float(jnp.asarray(sc))
    g["dyadic"] = {"s": sc.tolist(), "m": np.asarray(m).tolist(),
                   "k": np.asarray(k).tolist()}
    # requant_rows
    p = rng.integers(-(1 << 40), 1 << 40, (6, 32)).astype(np.int64)
    m_in = rng.integers(100, 250, 6).astype(np.int64)
    k_in = rng.integers(10, 30, 6).astype(np.int32)
    for bits in (4, 8):
        v, my, ky, zp = intops.requant_rows(
            jnp.asarray(p), jnp.asarray(m_in), jnp.asarray(k_in), bits)
        g[f"requant{bits}"] = {
            "p": p.tolist(), "m_in": m_in.tolist(), "k_in": k_in.tolist(),
            "vals": np.asarray(v).tolist(), "m": np.asarray(my).tolist(),
            "k": np.asarray(ky).tolist(), "zp": np.asarray(zp).tolist()}
    # di_exp
    xe = rng.integers(-500, 1, (5, 16)).astype(np.int32)
    me = rng.integers(128, 256, 5).astype(np.int32)
    ke = rng.integers(4, 12, 5).astype(np.int32)
    ye = intops.di_exp(jnp.asarray(xe), jnp.asarray(me), jnp.asarray(ke))
    g["di_exp"] = {"x": xe.tolist(), "m": me.tolist(), "k": ke.tolist(),
                   "y": np.asarray(ye).tolist()}
    # di_clipped_softmax (with mask)
    ps = (rng.normal(0, 3e5, (4, 12))).astype(np.int64)
    m1 = rng.integers(128, 256, 4).astype(np.int32)
    k1 = np.full(4, 12, np.int32)
    mask = np.tril(np.ones((4, 12), bool), 8)
    ys = intops.di_clipped_softmax(
        jnp.asarray(ps), jnp.asarray(m1), jnp.asarray(k1), 177, 11, 8,
        mask=jnp.asarray(mask))
    g["di_softmax"] = {"p": ps.tolist(), "m1": m1.tolist(),
                       "k1": k1.tolist(), "m2": 177, "k2": 11,
                       "mask": mask.astype(int).tolist(),
                       "y": np.asarray(ys).tolist()}
    # di_norm (both variants)
    xn = rng.integers(0, 256, (4, 24)).astype(np.int32)
    zpn = rng.integers(100, 150, 4).astype(np.int32)
    for cent, tag in ((False, "rms"), (True, "ln")):
        v, my, ky, zp = intops.di_norm(jnp.asarray(xn), jnp.asarray(zpn),
                                       8, cent)
        g[f"di_norm_{tag}"] = {
            "x": xn.tolist(), "zp": zpn.tolist(),
            "vals": np.asarray(v).tolist(), "m": np.asarray(my).tolist(),
            "k": np.asarray(ky).tolist(), "ozp": np.asarray(zp).tolist()}
    # di_swiglu
    xg = rng.integers(0, 256, (3, 16)).astype(np.int32)
    xu = rng.integers(0, 256, (3, 16)).astype(np.int32)
    mg = rng.integers(128, 256, 3).astype(np.int32)
    kg = np.full(3, 12, np.int32)
    zg = rng.integers(100, 150, 3).astype(np.int32)
    mu = rng.integers(128, 256, 3).astype(np.int32)
    ku = np.full(3, 13, np.int32)
    zu = rng.integers(100, 150, 3).astype(np.int32)
    am = rng.integers(128, 256, 16).astype(np.int32)
    ak = rng.integers(5, 9, 16).astype(np.int32)
    v, my, ky, zp = intops.di_swiglu(
        *(jnp.asarray(a) for a in (xg, mg, kg, zg, xu, mu, ku, zu, am, ak)),
        8, 8)
    g["di_swiglu"] = {
        "xg": xg.tolist(), "mg": mg.tolist(), "kg": kg.tolist(),
        "zpg": zg.tolist(), "xu": xu.tolist(), "mu": mu.tolist(),
        "ku": ku.tolist(), "zpu": zu.tolist(), "am": am.tolist(),
        "ak": ak.tolist(), "vals": np.asarray(v).tolist(),
        "m": np.asarray(my).tolist(), "k": np.asarray(ky).tolist(),
        "zp": np.asarray(zp).tolist()}
    # di_add
    xa = rng.integers(0, 256, (4, 16)).astype(np.int32)
    xb = rng.integers(0, 256, (4, 16)).astype(np.int32)
    ma = rng.integers(128, 256, 4).astype(np.int32)
    ka = rng.integers(10, 14, 4).astype(np.int32)
    za = rng.integers(100, 150, 4).astype(np.int32)
    mb = rng.integers(128, 256, 4).astype(np.int32)
    kb = rng.integers(10, 14, 4).astype(np.int32)
    zb = rng.integers(100, 150, 4).astype(np.int32)
    v, my, ky, zp = intops.di_add(
        *(jnp.asarray(a) for a in (xa, ma, ka, za, xb, mb, kb, zb)), 8)
    g["di_add"] = {
        "xa": xa.tolist(), "ma": ma.tolist(), "ka": ka.tolist(),
        "za": za.tolist(), "xb": xb.tolist(), "mb": mb.tolist(),
        "kb": kb.tolist(), "zb": zb.tolist(),
        "vals": np.asarray(v).tolist(), "m": np.asarray(my).tolist(),
        "k": np.asarray(ky).tolist(), "zp": np.asarray(zp).tolist()}
    # di_linear (with and without bias)
    x = rng.integers(0, 256, (4, 24)).astype(np.int32)
    mx = rng.integers(128, 256, 4).astype(np.int32)
    kx = np.full(4, 12, np.int32)
    zx = rng.integers(100, 150, 4).astype(np.int32)
    wq = rng.integers(-127, 128, (24, 12)).astype(np.int32)
    mw = rng.integers(100, 1 << 14, 12).astype(np.int32)
    kw = 18
    bq = rng.integers(-(1 << 20), 1 << 20, 12).astype(np.int64)
    for bias, tag in ((None, "nobias"), (bq, "bias")):
        b = None if bias is None else jnp.asarray(bias)
        v, my, ky, zp = intops.di_linear(
            jnp.asarray(x), jnp.asarray(mx), jnp.asarray(kx),
            jnp.asarray(zx), jnp.asarray(wq), jnp.asarray(mw),
            jnp.asarray(kw, I32), b, 8)
        g[f"di_linear_{tag}"] = {
            "x": x.tolist(), "mx": mx.tolist(), "kx": kx.tolist(),
            "zpx": zx.tolist(), "wq": wq.tolist(), "mw": mw.tolist(),
            "kw": kw, "bq": (bias.tolist() if bias is not None else None),
            "vals": np.asarray(v).tolist(), "m": np.asarray(my).tolist(),
            "k": np.asarray(ky).tolist(), "zp": np.asarray(zp).tolist()}
    # requant_common
    v, m, k, zp = intops.requant_common(
        jnp.asarray(x), jnp.asarray(mx), jnp.asarray(kx), jnp.asarray(zx), 8)
    g["requant_common"] = {
        "x": x.tolist(), "mx": mx.tolist(), "kx": kx.tolist(),
        "zpx": zx.tolist(), "vals": np.asarray(v).tolist(),
        "m": int(m), "k": int(k), "zp": int(zp)}
    # di_rope
    cos_q, sin_q = intops.rope_tables(8, 6)
    xr = rng.integers(0, 256, (6, 2, 8)).astype(np.int32)
    zr = rng.integers(100, 150, 6).astype(np.int32)
    yr = intops.di_rope(jnp.asarray(xr), jnp.asarray(zr),
                        jnp.asarray(cos_q), jnp.asarray(sin_q))
    g["di_rope"] = {"x": xr.tolist(), "zp": zr.tolist(),
                    "cos": cos_q.tolist(), "sin": sin_q.tolist(),
                    "y": np.asarray(yr).tolist()}
    return g


def model_goldens(out_dir: str, models: list, seq: int = 48) -> dict:
    """End-to-end logits fingerprints: rust native engines must reproduce
    the FP logits within tolerance and the int logits structure."""
    g = {}
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 256, seq).astype(np.int32)
    for name in models:
        wpath = os.path.join(out_dir, f"{name}.weights.bin")
        if not os.path.exists(wpath):
            continue
        params, meta = train.load_weights(wpath)
        cfg = ModelConfig.from_dict(meta["config"])
        fp = np.asarray(fp_forward(cfg, params, jnp.asarray(toks)))
        qp = int_params_from_fp(cfg, params, SCHEMES["w8a8"])
        iq = np.asarray(int_forward(cfg, qp, jnp.asarray(toks),
                                    SCHEMES["w8a8"]))
        g[name] = {
            "tokens": toks.tolist(),
            "fp_logits_last": fp[-1, :16].astype(float).tolist(),
            "fp_logits_sum": float(fp.sum()),
            "int_w8a8_logits_last": iq[-1, :16].astype(float).tolist(),
        }
    return g


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=350)
    ap.add_argument("--fast", action="store_true",
                    help="small models only, fewer steps (CI/dev)")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    models = (["tinyllama_s", "tinyopt_s"] if args.fast
              else list(PRESETS))
    steps = 120 if args.fast else args.steps

    # 1. corpus + training (skipped if weights exist and not forced)
    need_train = [m for m in models if not os.path.exists(
        os.path.join(out, f"{m}.weights.bin"))]
    if need_train and not args.skip_train:
        train.train_all(out, steps=steps, models=need_train)

    manifest = {"models": {}, "hlo": [], "kernels": {}, "schemes":
                {k: {"w_bits": v.w_bits, "a_bits": v.a_bits}
                 for k, v in SCHEMES.items()},
                "seq_buckets": list(SEQ_BUCKETS)}

    # 2. model HLO artifacts.
    # fp_forward compiles in <1s on the CPU PJRT client; the full
    # integer graph does NOT (XLA CPU compile is superlinear in
    # instruction count: 1 layer ~ 19s, 4 layers ~ 5min on this box), so
    # the AOT integer artifact is a ONE-LAYER block (same param contract
    # with n_layers=1) — the rust native-vs-PJRT integration test proves
    # the whole DI-* pipeline composes through XLA. Full-depth integer
    # inference runs on the rust native engine. See DESIGN.md §Artifacts.
    import dataclasses

    for name in models:
        wpath = os.path.join(out, f"{name}.weights.bin")
        _, meta = train.load_weights(wpath)
        cfg = ModelConfig.from_dict(meta["config"])
        manifest["models"][name] = {
            "config": cfg.to_dict(), "weights": f"{name}.weights.bin",
            "final_loss": meta.get("final_loss")}
        for seq in SEQ_BUCKETS:
            text, pmeta = lower_fp_forward(cfg, seq)
            fn = f"fp_forward.{name}.s{seq}.hlo.txt"
            _write(os.path.join(out, fn), text)
            manifest["hlo"].append({
                "kind": "fp_forward", "model": name, "seq": seq,
                "file": fn, "params": pmeta,
                "outputs": [{"shape": [seq, cfg.vocab], "dtype": "f32"}]})
            print(f"  wrote {fn} ({len(text)//1024} KiB)")

    # integer one-layer block artifacts for the two small models
    block_seq = 32
    for name in [m for m in ("tinyllama_s", "tinyopt_s") if m in models]:
        _, meta = train.load_weights(os.path.join(out,
                                                  f"{name}.weights.bin"))
        cfg = ModelConfig.from_dict(meta["config"])
        bcfg = dataclasses.replace(cfg, n_layers=1)
        for tag in ("w8a8", "w4a4"):
            text, pmeta = lower_int_forward(bcfg, SCHEMES[tag], block_seq)
            fn = f"int_block.{name}.{tag}.s{block_seq}.hlo.txt"
            _write(os.path.join(out, fn), text)
            manifest["hlo"].append({
                "kind": "int_block", "model": name, "seq": block_seq,
                "scheme": tag, "n_layers": 1, "file": fn, "params": pmeta,
                "outputs": [{"shape": [block_seq, cfg.vocab],
                             "dtype": "f32"}]})
            print(f"  wrote {fn} ({len(text)//1024} KiB)")

    # 3. L1 kernel artifact
    ktext = lower_di_matmul_kernel()
    _write(os.path.join(out, "kernels", "di_matmul.hlo.txt"), ktext)
    manifest["kernels"]["di_matmul"] = {
        "file": "kernels/di_matmul.hlo.txt", "t": 64, "k": 128, "n": 128,
        "kw": 12, "out_bits": 8}
    print(f"  wrote kernels/di_matmul.hlo.txt ({len(ktext)//1024} KiB)")

    # 4. goldens
    g = make_goldens()
    g["models"] = model_goldens(out, models)
    with open(os.path.join(out, "goldens.json"), "w") as f:
        json.dump(g, f)
    print("  wrote goldens.json")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("  wrote manifest.json")


if __name__ == "__main__":
    main()
