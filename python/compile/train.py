"""Build-time trainer: tiny LLaMA/OPT models on the synthetic corpus.

The paper quantizes pretrained LLaMA/OPT checkpoints; we have none, so
`make artifacts` trains byte-level stand-ins (~0.5-2M params) with AdamW
on the deterministic corpus from `corpus.py`, then applies the
OUTLIER-INJECTION pass (DESIGN.md S17): a function-preserving rewrite
that concentrates large per-channel scales in exactly the
activation-weight pairs FSBR smooths —

  * norm gamma  <- gamma * s,  following linear rows <- rows / s
    (post-norm activations develop channel outliers; paper Fig. 1)
  * wu columns  <- * s, wd rows <- / s   (SwiGLU up path; paper Fig. 2)
  * wv columns  <- * s, wo rows <- / s   (attention v->o path)

Each rewrite leaves the FP function bit-identical in exact arithmetic but
makes naive per-tensor quantization collapse, reproducing the failure
mode the paper attributes to LLMs. FSBR can (and does) learn the inverse.

Python runs at build time only; the weights go to artifacts/ in a
safetensors-like container the rust runtime reads.
"""

from __future__ import annotations

import json
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, PRESETS, fp_forward, fp_param_spec, init_params

F32 = jnp.float32


# ---------------------------------------------------------------------------
# weights container (JSON header + raw little-endian tensors)
# ---------------------------------------------------------------------------

def save_weights(path: str, tensors: dict, meta: dict | None = None):
    header = {"__meta__": meta or {}}
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        dt = {"float32": "f32", "int32": "i32", "int64": "i64"}[str(arr.dtype)]
        nb = arr.nbytes
        header[name] = {"dtype": dt, "shape": list(arr.shape),
                        "offset": offset, "nbytes": nb}
        blobs.append(arr.tobytes())
        offset += nb
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def load_weights(path: str) -> tuple[dict, dict]:
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        data = f.read()
    meta = header.pop("__meta__", {})
    out = {}
    for name, info in header.items():
        dt = {"f32": np.float32, "i32": np.int32, "i64": np.int64}[info["dtype"]]
        a = np.frombuffer(data, dt, count=int(np.prod(info["shape"]) or 1),
                          offset=info["offset"])
        out[name] = a.reshape(info["shape"])
    return out, meta


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def batches(tokens: np.ndarray, seq: int, bsz: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, bsz)
        x = np.stack([tokens[i:i + seq] for i in idx])
        y = np.stack([tokens[i + 1:i + seq + 1] for i in idx])
        yield jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def train_model(cfg: ModelConfig, text: str, steps: int = 400,
                seq: int = 128, bsz: int = 16, lr: float = 3e-3,
                seed: int = 0, log=print) -> dict:
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, seed).items()}
    toks = np.asarray(corpus.encode(text), np.int32)

    def loss_fn(p, x, y):
        logits = jax.vmap(lambda t: fp_forward(cfg, p, t))(x)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None], axis=-1)
        return -jnp.mean(ll)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # AdamW (minimal, no schedule beyond linear warmup)
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}
    var = {k: jnp.zeros_like(v) for k, v in params.items()}
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01

    @jax.jit
    def update(p, m, v, g, step, lr_t):
        new_p, new_m, new_v = {}, {}, {}
        for k in p:
            new_m[k] = b1 * m[k] + (1 - b1) * g[k]
            new_v[k] = b2 * v[k] + (1 - b2) * g[k] * g[k]
            mhat = new_m[k] / (1 - b1 ** step)
            vhat = new_v[k] / (1 - b2 ** step)
            upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p[k]
            new_p[k] = p[k] - lr_t * upd
        return new_p, new_m, new_v

    losses = []
    for step, (x, y) in enumerate(batches(toks, seq, bsz, steps, seed + 1),
                                  start=1):
        lr_t = lr * min(1.0, step / 40)
        loss, g = grad_fn(params, x, y)
        params, mom, var = update(params, mom, var, g, step, lr_t)
        losses.append(float(loss))
        if step % 50 == 0 or step == 1:
            log(f"  [{cfg.name}] step {step:4d} loss {float(loss):.4f}")
    return {k: np.asarray(v) for k, v in params.items()}, losses


# ---------------------------------------------------------------------------
# outlier injection (S17) — function-preserving channel-scale pathology
# ---------------------------------------------------------------------------

def inject_outliers(cfg: ModelConfig, params: dict, frac: float = 0.06,
                    lo: float = 8.0, hi: float = 32.0, seed: int = 7) -> dict:
    """See module docstring. Returns a new params dict; FP function is
    unchanged (up to float rounding), activation statistics are not."""
    rng = np.random.default_rng(seed)
    p = {k: np.asarray(v, np.float64).copy() for k, v in params.items()}

    def chan_scales(n):
        s = np.ones(n)
        k = max(1, int(n * frac))
        idx = rng.choice(n, k, replace=False)
        s[idx] = rng.uniform(lo, hi, k)
        return s

    for i in range(cfg.n_layers):
        d = cfg.d_model
        # norm1 -> qkv
        s1 = chan_scales(d)
        p[f"layers.{i}.norm1.g"] *= s1
        if cfg.arch == "opt":
            p[f"layers.{i}.norm1.b"] *= s1
        for w in ("wq", "wk", "wv"):
            p[f"layers.{i}.attn.{w}"] /= s1[:, None]
        # norm2 -> mlp in
        s2 = chan_scales(d)
        p[f"layers.{i}.norm2.g"] *= s2
        if cfg.arch == "opt":
            p[f"layers.{i}.norm2.b"] *= s2
        ins = ("wg", "wu") if cfg.arch == "llama" else ("w1",)
        for w in ins:
            p[f"layers.{i}.mlp.{w}"] /= s2[:, None]
        # v -> o (linear path through attention)
        sv = chan_scales(d)
        p[f"layers.{i}.attn.wv"] *= sv[None, :]
        if cfg.arch == "opt":
            p[f"layers.{i}.attn.wv.b"] *= sv
        p[f"layers.{i}.attn.wo"] /= sv[:, None]
        # up -> down (SwiGLU up path is linear; ReLU path is
        # positively-homogeneous so scaling also commutes for opt)
        sup = chan_scales(cfg.d_ff)
        upn = "wu" if cfg.arch == "llama" else "w1"
        dnn = "wd" if cfg.arch == "llama" else "w2"
        p[f"layers.{i}.mlp.{upn}"] *= sup[None, :]
        if cfg.arch == "opt":
            p[f"layers.{i}.mlp.{upn}.b"] *= sup
        p[f"layers.{i}.mlp.{dnn}"] /= sup[:, None]
    return {k: np.asarray(v, np.float32) for k, v in p.items()}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def train_all(out_dir: str, corpus_chars: int = 400_000, steps: int = 400,
              models=None, log=print) -> dict:
    import os

    os.makedirs(out_dir, exist_ok=True)
    text = corpus.generate(corpus_chars, seed=1234)
    train_text, val_text = corpus.train_val_split(text)
    with open(os.path.join(out_dir, "corpus.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, "corpus.meta.json"), "w") as f:
        json.dump({"n_chars": len(text), "train_chars": len(train_text),
                   "val_chars": len(val_text), "seed": 1234}, f)
    summary = {}
    for name in (models or list(PRESETS)):
        cfg = PRESETS[name]
        log(f"training {name} ({cfg.arch}, d={cfg.d_model}, "
            f"L={cfg.n_layers}) ...")
        params, losses = train_model(cfg, train_text, steps=steps, log=log)
        params = inject_outliers(cfg, params)
        meta = {"config": cfg.to_dict(), "final_loss": losses[-1],
                "steps": steps}
        save_weights(os.path.join(out_dir, f"{name}.weights.bin"),
                     params, meta)
        summary[name] = {"final_loss": losses[-1],
                         "loss_curve": losses[::25]}
    return summary


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 400
    s = train_all(out, steps=steps)
    print(json.dumps(s, indent=1))
