"""Deterministic synthetic corpus generator.

The paper evaluates on WikiText2/C4, which we do not have. This module
generates an English-like templated corpus with enough compositional
structure (grammar, agreement, copy/induction patterns) that a tiny
byte-level transformer learns non-trivial statistics, and that quantization
error shows up as a measurable perplexity delta.

The SAME text is consumed by the rust side (artifacts/corpus.txt), so the
generator lives only here; rust never re-generates it. Determinism: a
simple xorshift PRNG seeded explicitly — no dependence on python hash
randomization or numpy version.
"""

from __future__ import annotations

SUBJECTS = [
    "the engineer", "a quiet student", "the old captain", "my neighbor",
    "the tired doctor", "a young painter", "the night guard", "the chess player",
    "an honest merchant", "the river pilot", "the clockmaker", "a wandering poet",
]
SUBJECTS_PL = [
    "the engineers", "two quiet students", "the old captains", "my neighbors",
    "the tired doctors", "some young painters", "the night guards",
    "the chess players", "honest merchants", "the river pilots",
]
VERBS_S = [
    "builds", "paints", "repairs", "studies", "watches", "measures",
    "records", "carries", "designs", "inspects", "sharpens", "collects",
]
VERBS_P = [
    "build", "paint", "repair", "study", "watch", "measure",
    "record", "carry", "design", "inspect", "sharpen", "collect",
]
OBJECTS = [
    "a small bridge", "the copper lantern", "an iron gate", "the wooden boat",
    "a stone tower", "the broken compass", "a silver bell", "the long ladder",
    "an oak table", "the narrow road", "a glass prism", "the heavy anchor",
]
PLACES = [
    "near the harbor", "behind the mill", "under the archway", "by the canal",
    "inside the workshop", "at the market", "on the hillside", "along the pier",
    "beside the granary", "within the old walls",
]
TIMES = [
    "every morning", "before dawn", "after the storm", "in late autumn",
    "during the festival", "on quiet evenings", "at the turn of the tide",
    "when the bells ring", "in the dry season",
]
CONNECT = [
    "and then", "but later", "so that", "because", "although", "while",
]
ADJ = [
    "careful", "patient", "curious", "steady", "practical", "stubborn",
    "cheerful", "precise", "weary", "bold",
]


class XorShift:
    """xorshift32 — deterministic across platforms/versions."""

    def __init__(self, seed: int):
        self.s = (seed & 0xFFFFFFFF) or 0x9E3779B9

    def next(self) -> int:
        x = self.s
        x ^= (x << 13) & 0xFFFFFFFF
        x ^= x >> 17
        x ^= (x << 5) & 0xFFFFFFFF
        self.s = x
        return x

    def randint(self, n: int) -> int:
        return self.next() % n

    def choice(self, seq):
        return seq[self.randint(len(seq))]


def _sentence(rng: XorShift) -> str:
    kind = rng.randint(10)
    if kind < 4:
        # simple SVO with agreement
        if rng.randint(2) == 0:
            s, v = rng.choice(SUBJECTS), rng.choice(VERBS_S)
        else:
            s, v = rng.choice(SUBJECTS_PL), rng.choice(VERBS_P)
        return f"{s} {v} {rng.choice(OBJECTS)} {rng.choice(PLACES)}."
    if kind < 6:
        # temporal clause
        s, v = rng.choice(SUBJECTS), rng.choice(VERBS_S)
        return f"{rng.choice(TIMES)}, {s} {v} {rng.choice(OBJECTS)}."
    if kind < 8:
        # compound with connector
        s1, v1 = rng.choice(SUBJECTS), rng.choice(VERBS_S)
        s2, v2 = rng.choice(SUBJECTS_PL), rng.choice(VERBS_P)
        return (
            f"{s1} {v1} {rng.choice(OBJECTS)} {rng.choice(CONNECT)} "
            f"{s2} {v2} {rng.choice(OBJECTS)} {rng.choice(PLACES)}."
        )
    if kind < 9:
        # copular with adjective
        return f"{rng.choice(SUBJECTS)} is {rng.choice(ADJ)} {rng.choice(TIMES)}."
    # induction-friendly repetition: "X built Y. X admired Y."
    s = rng.choice(SUBJECTS)
    o = rng.choice(OBJECTS)
    v1, v2 = rng.choice(VERBS_S), rng.choice(VERBS_S)
    return f"{s} {v1} {o}. later {s} also {v2} {o}."


def generate(n_chars: int, seed: int = 1234) -> str:
    rng = XorShift(seed)
    parts: list[str] = []
    total = 0
    while total < n_chars:
        para_len = 3 + rng.randint(5)
        sents = [_sentence(rng) for _ in range(para_len)]
        para = " ".join(sents) + "\n"
        parts.append(para)
        total += len(para)
    return "".join(parts)[:n_chars]


def train_val_split(text: str, val_frac: float = 0.1) -> tuple[str, str]:
    cut = int(len(text) * (1.0 - val_frac))
    # split on a newline boundary so no sentence straddles the split
    nl = text.rfind("\n", 0, cut)
    if nl > 0:
        cut = nl + 1
    return text[:cut], text[cut:]


def encode(text: str) -> list[int]:
    """Byte-level tokenization (vocab = 256). Mirrors rust data::tokenizer."""
    return list(text.encode("utf-8"))


if __name__ == "__main__":
    import sys

    n = int(sys.argv[1]) if len(sys.argv) > 1 else 400_000
    sys.stdout.write(generate(n))
