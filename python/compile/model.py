"""L2: the paper's model layer — FP reference transformer + integer-only
transformer, both in JAX, both AOT-lowered to HLO for the rust runtime.

Two architectures (matching the paper's evaluation families):
  * "llama": pre-RMSNorm, RoPE attention, SwiGLU MLP, no biases
  * "opt":   pre-LayerNorm, learned position embeddings, ReLU MLP, biases

The integer model is built exclusively from `intops` (the DI-* operator
spec) — its computational graph is integer-only end to end; the single
float op is the final logits dequantization at the model boundary.

Weights enter the integer model ALREADY quantized and FSBR-folded (the
rust L3 quantizer produces them); this module defines the parameter
ordering contract (`int_param_spec` / `fp_param_spec`) that the rust
runtime uses to feed PJRT executables.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import intops
from .intops import I32, I64

F32 = jnp.float32


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    arch: str  # "llama" | "opt"
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    max_seq: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    name: str = "tinyllama_s"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self):
        return {
            "arch": self.arch, "vocab": self.vocab, "d_model": self.d_model,
            "n_layers": self.n_layers, "n_heads": self.n_heads,
            "d_ff": self.d_ff, "max_seq": self.max_seq,
            "rope_theta": self.rope_theta, "norm_eps": self.norm_eps,
            "name": self.name,
        }

    @staticmethod
    def from_dict(d):
        return ModelConfig(**d)


@dataclass(frozen=True)
class QuantScheme:
    """Quantization configuration (paper notation WxAy)."""
    w_bits: int = 8
    a_bits: int = 8
    softmax_bits: int = 8  # p_out of DI-ClippedSoftmax (paper: 8)
    sig_bits: int = 8      # p_sig of DI-SwiGLU
    clip: tuple = (intops.CLIP_M, intops.CLIP_K)  # DI-ClippedSoftmax c

    @property
    def tag(self) -> str:
        return f"w{self.w_bits}a{self.a_bits}"


PRESETS = {
    # LLaMA family stand-ins (paper: 7B/13B/30B -> S/M/L)
    "tinyllama_s": ModelConfig("llama", d_model=128, n_layers=4, n_heads=4,
                               d_ff=256, name="tinyllama_s"),
    "tinyllama_m": ModelConfig("llama", d_model=192, n_layers=6, n_heads=6,
                               d_ff=384, name="tinyllama_m"),
    "tinyllama_l": ModelConfig("llama", d_model=256, n_layers=8, n_heads=8,
                               d_ff=512, name="tinyllama_l"),
    # OPT family stand-ins (paper: 6.7B/13B/30B -> S/M)
    "tinyopt_s": ModelConfig("opt", d_model=128, n_layers=4, n_heads=4,
                             d_ff=512, name="tinyopt_s"),
    "tinyopt_m": ModelConfig("opt", d_model=192, n_layers=6, n_heads=6,
                             d_ff=768, name="tinyopt_m"),
}


# ---------------------------------------------------------------------------
# FP parameters
# ---------------------------------------------------------------------------

def _linears(cfg: ModelConfig, i: int) -> list:
    base = [f"layers.{i}.attn.wq", f"layers.{i}.attn.wk",
            f"layers.{i}.attn.wv", f"layers.{i}.attn.wo"]
    if cfg.arch == "llama":
        base += [f"layers.{i}.mlp.wg", f"layers.{i}.mlp.wu",
                 f"layers.{i}.mlp.wd"]
    else:
        base += [f"layers.{i}.mlp.w1", f"layers.{i}.mlp.w2"]
    return base


def _linear_shape(cfg: ModelConfig, name: str):
    d, f = cfg.d_model, cfg.d_ff
    kind = name.rsplit(".", 1)[1]
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "wg": (d, f), "wu": (d, f), "wd": (f, d),
        "w1": (d, f), "w2": (f, d),
    }[kind]


def fp_param_spec(cfg: ModelConfig) -> list:
    """Ordered (name, shape) list — the FP weights contract."""
    spec = [("embed", (cfg.vocab, cfg.d_model))]
    if cfg.arch == "opt":
        spec.append(("pos_embed", (cfg.max_seq, cfg.d_model)))
    for i in range(cfg.n_layers):
        for ln in _linears(cfg, i):
            spec.append((ln, _linear_shape(cfg, ln)))
            if cfg.arch == "opt":
                spec.append((ln + ".b", (_linear_shape(cfg, ln)[1],)))
        spec.append((f"layers.{i}.norm1.g", (cfg.d_model,)))
        spec.append((f"layers.{i}.norm2.g", (cfg.d_model,)))
        if cfg.arch == "opt":
            spec.append((f"layers.{i}.norm1.b", (cfg.d_model,)))
            spec.append((f"layers.{i}.norm2.b", (cfg.d_model,)))
    spec.append(("final_norm.g", (cfg.d_model,)))
    if cfg.arch == "opt":
        spec.append(("final_norm.b", (cfg.d_model,)))
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in fp_param_spec(cfg):
        if name.endswith(".g"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith(".b"):
            params[name] = np.zeros(shape, np.float32)
        elif name in ("embed", "pos_embed"):
            params[name] = rng.normal(0, 0.02, shape).astype(np.float32)
        else:
            fan_in = shape[0]
            std = (2.0 / (fan_in + shape[1])) ** 0.5
            params[name] = rng.normal(0, std, shape).astype(np.float32)
    return params


# ---------------------------------------------------------------------------
# FP forward (f32) — the reference the paper quantizes
# ---------------------------------------------------------------------------

def _fp_norm(x, g, b, eps, centered):
    if centered:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        x = x - mu
    v = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x / jnp.sqrt(v + eps) * g
    if b is not None:
        y = y + b
    return y


def _fp_rope(x, cfg: ModelConfig, pos0=0):
    t, _, d = x.shape
    half = d // 2
    inv = 1.0 / (cfg.rope_theta ** (np.arange(half, dtype=np.float64) / half))
    ang = (np.arange(t, dtype=np.float64) + pos0)[:, None] * inv[None, :]
    c = jnp.asarray(np.cos(ang), F32)[:, None, :]
    s = jnp.asarray(np.sin(ang), F32)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def fp_forward(cfg: ModelConfig, params: dict, tokens):
    """tokens (T,) i32 -> logits (T, V) f32. Causal, single sequence."""
    t = tokens.shape[0]
    x = jnp.asarray(params["embed"], F32)[tokens]
    if cfg.arch == "opt":
        x = x + jnp.asarray(params["pos_embed"], F32)[:t]
    h_dim, n_h = cfg.head_dim, cfg.n_heads
    mask = np.tril(np.ones((t, t), bool))
    centered = cfg.arch == "opt"
    for i in range(cfg.n_layers):
        p = lambda s: jnp.asarray(params[f"layers.{i}.{s}"], F32)
        pb = (lambda s: jnp.asarray(params[f"layers.{i}.{s}"], F32)
              if cfg.arch == "opt" else None)
        pbx = lambda s: (jnp.asarray(params[f"layers.{i}.{s}"], F32)
                         if cfg.arch == "opt" else None)
        h = _fp_norm(x, p("norm1.g"), pbx("norm1.b"), cfg.norm_eps, centered)
        q = h @ p("attn.wq")
        k = h @ p("attn.wk")
        v = h @ p("attn.wv")
        if cfg.arch == "opt":
            q = q + p("attn.wq.b")
            k = k + p("attn.wk.b")
            v = v + p("attn.wv.b")
        q = q.reshape(t, n_h, h_dim)
        k = k.reshape(t, n_h, h_dim)
        v = v.reshape(t, n_h, h_dim)
        if cfg.arch == "llama":
            q, k = _fp_rope(q, cfg), _fp_rope(k, cfg)
        scores = jnp.einsum("thd,shd->hts", q, k)
        scores = jnp.where(mask[None], scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hts,shd->thd", probs, v).reshape(t, cfg.d_model)
        o = o @ p("attn.wo")
        if cfg.arch == "opt":
            o = o + p("attn.wo.b")
        x = x + o
        h = _fp_norm(x, p("norm2.g"), pbx("norm2.b"), cfg.norm_eps, centered)
        if cfg.arch == "llama":
            gate = h @ p("mlp.wg")
            up = h @ p("mlp.wu")
            act = gate * jax.nn.sigmoid(gate) * up
            y = act @ p("mlp.wd")
        else:
            a = jax.nn.relu(h @ p("mlp.w1") + p("mlp.w1.b"))
            y = a @ p("mlp.w2") + p("mlp.w2.b")
        x = x + y
    fb = (jnp.asarray(params["final_norm.b"], F32)
          if cfg.arch == "opt" else None)
    x = _fp_norm(x, jnp.asarray(params["final_norm.g"], F32), fb,
                 cfg.norm_eps, centered)
    return x @ jnp.asarray(params["embed"], F32).T


# ---------------------------------------------------------------------------
# integer-only parameters contract
# ---------------------------------------------------------------------------

def int_param_spec(cfg: ModelConfig) -> list:
    """Ordered (name, shape, dtype) — the quantized-weights contract fed
    to the AOT int_forward executable by the rust runtime. Weight scales
    per output channel at one common exponent; norm gammas are folded
    into the following linear offline (see rust calib::fold)."""
    d, v, s = cfg.d_model, cfg.vocab, cfg.max_seq
    spec = [
        ("embed.vals", (v, d), "i32"), ("embed.m", (v,), "i32"),
        ("embed.k", (v,), "i32"), ("embed.zp", (v,), "i32"),
    ]
    if cfg.arch == "opt":
        spec += [
            ("pos_embed.vals", (s, d), "i32"), ("pos_embed.m", (s,), "i32"),
            ("pos_embed.k", (s,), "i32"), ("pos_embed.zp", (s,), "i32"),
        ]
    if cfg.arch == "llama":
        half = cfg.head_dim // 2
        spec += [("rope.cos", (s, half), "i32"),
                 ("rope.sin", (s, half), "i32")]
    for i in range(cfg.n_layers):
        for ln in _linears(cfg, i):
            kk, nn = _linear_shape(cfg, ln)
            spec += [(ln + ".wq", (kk, nn), "i32"),
                     (ln + ".mw", (nn,), "i32"),
                     (ln + ".kw", (1,), "i32")]
            if cfg.arch == "opt":
                spec.append((ln + ".bq", (nn,), "i64"))
        if cfg.arch == "llama":
            spec += [(f"layers.{i}.alpha_m", (cfg.d_ff,), "i32"),
                     (f"layers.{i}.alpha_k", (cfg.d_ff,), "i32")]
    spec += [("lm_head.wq", (d, v), "i32"), ("lm_head.mw", (v,), "i32"),
             ("lm_head.kw", (1,), "i32")]
    if cfg.arch == "opt":
        spec.append(("lm_head.bq", (v,), "i64"))
    return spec


def int_params_from_fp(cfg: ModelConfig, params: dict,
                       scheme: QuantScheme, alpha=None) -> dict:
    """Python-side quantization (for tests & goldens; rust L3 has its own).

    Folds norm gammas (and betas, for opt) into the following linears,
    quantizes weights per-channel symmetric to w_bits, embedding and
    positional tables per-row asymmetric 8-bit.
    alpha: optional per-layer (d_ff,) act-smooth factors (FSBR); the gate
    weight columns are multiplied by alpha and alpha is handed to
    DI-SwiGLU as the dyadic de-smoothing constant.
    """
    out = {}
    ev, em, ek, ezp = intops.quantize_f32(jnp.asarray(params["embed"]), 8)
    out.update({"embed.vals": ev, "embed.m": em, "embed.k": ek,
                "embed.zp": ezp})
    if cfg.arch == "opt":
        pv, pm, pk, pzp = intops.quantize_f32(
            jnp.asarray(params["pos_embed"]), 8)
        out.update({"pos_embed.vals": pv, "pos_embed.m": pm,
                    "pos_embed.k": pk, "pos_embed.zp": pzp})
    if cfg.arch == "llama":
        cos_q, sin_q = intops.rope_tables(cfg.head_dim, cfg.max_seq,
                                          cfg.rope_theta)
        out["rope.cos"] = jnp.asarray(cos_q)
        out["rope.sin"] = jnp.asarray(sin_q)

    def quant_linear(prefix, w, b=None):
        qmax = (1 << (scheme.w_bits - 1)) - 1
        sc = np.maximum(np.abs(np.asarray(w)).max(axis=0), 1e-8) / qmax
        mw, kw = intops.align_channel_scales(jnp.asarray(sc))
        s_d = np.asarray(mw, np.float64) / np.exp2(float(kw))
        wq = jnp.clip(
            intops.round_half_away(jnp.asarray(w, jnp.float64)
                                   / s_d[None, :]),
            -qmax, qmax).astype(I32)
        out[prefix + ".wq"] = wq
        out[prefix + ".mw"] = mw
        out[prefix + ".kw"] = jnp.asarray(kw, I32).reshape((1,))
        if cfg.arch == "opt":
            bb = b if b is not None else np.zeros(w.shape[1], np.float64)
            out[prefix + ".bq"] = intops.bias_quantize(jnp.asarray(bb))

    for i in range(cfg.n_layers):
        g1 = np.asarray(params[f"layers.{i}.norm1.g"], np.float64)
        g2 = np.asarray(params[f"layers.{i}.norm2.g"], np.float64)
        for ln in _linears(cfg, i):
            w = np.asarray(params[ln], np.float64).copy()
            kind = ln.rsplit(".", 1)[1]
            b = params.get(ln + ".b")
            b = None if b is None else np.asarray(b, np.float64).copy()
            # fold norm gamma (and beta for opt) into the linear:
            #   (norm(x)*g + beta) @ W + b = norm(x) @ (g[:,None]*W)
            #                                + (b + beta @ W)
            if kind in ("wq", "wk", "wv"):
                if cfg.arch == "opt" and b is not None:
                    beta = np.asarray(params[f"layers.{i}.norm1.b"],
                                      np.float64)
                    b = b + beta @ w
                w = w * g1[:, None]
            if kind in ("wg", "wu", "w1"):
                if cfg.arch == "opt" and b is not None and kind == "w1":
                    beta = np.asarray(params[f"layers.{i}.norm2.b"],
                                      np.float64)
                    b = b + beta @ w
                w = w * g2[:, None]
            if kind == "wg" and alpha is not None:
                w = w * np.asarray(alpha[i], np.float64)[None, :]
            quant_linear(ln, w, b)
        if cfg.arch == "llama":
            a = (np.ones(cfg.d_ff, np.float64) if alpha is None
                 else np.asarray(alpha[i], np.float64))
            am, ak = intops.dyadic_from_float(jnp.asarray(a))
            out[f"layers.{i}.alpha_m"] = am
            out[f"layers.{i}.alpha_k"] = ak
    gf = np.asarray(params["final_norm.g"], np.float64)
    emb_t = np.asarray(params["embed"], np.float64).T
    lm_w = emb_t * gf[:, None]
    if cfg.arch == "opt":
        # final-norm beta folds into a logits bias
        lm_b = np.asarray(params["final_norm.b"], np.float64) @ emb_t
    else:
        lm_b = None
    quant_linear("lm_head", lm_w, lm_b)
    return out


# ---------------------------------------------------------------------------
# integer-only forward
# ---------------------------------------------------------------------------

def _heads_merge_requant(o3, vm, vk, p_bits, a_bits):
    """Merge per-head raw PV products into per-token DynQ rows.

    o3: (H, T, hd) i64 with per-head scale vm[h]/2^(vk[h]+p-1).
    Aligns heads to a common exponent then requantizes per token.
    """
    h, t, hd = o3.shape
    kcom = jnp.max(vk)
    sh = jnp.minimum(kcom - vk, 32).astype(I32)
    aligned = o3 * (vm.astype(I64) << sh)[:, None, None]
    y = jnp.transpose(aligned, (1, 0, 2)).reshape(t, h * hd)
    m_in = jnp.ones((t,), I64)
    k_in = jnp.zeros((t,), I32) + kcom + (p_bits - 1)
    return intops.requant_rows(y, m_in, k_in, a_bits)


def int_forward(cfg: ModelConfig, qp: dict, tokens,
                scheme: QuantScheme = QuantScheme()):
    """tokens (T,) i32 -> logits (T, V) f32 via integer-only ops.

    The graph is integer-only except the final dequantization multiply.
    Mirrored by rust int_model::IntModel::forward_full.
    """
    t = int(tokens.shape[0])
    a_bits = scheme.a_bits
    nl_bits = 8  # non-linear operator activations stay 8-bit (paper §4)
    # embedding gather: per-row quantized table -> per-token DynQ
    x = qp["embed.vals"][tokens]
    xm = qp["embed.m"][tokens]
    xk = qp["embed.k"][tokens]
    xzp = qp["embed.zp"][tokens]
    if cfg.arch == "opt":
        x, xm, xk, xzp = intops.di_add(
            x, xm, xk, xzp,
            qp["pos_embed.vals"][:t], qp["pos_embed.m"][:t],
            qp["pos_embed.k"][:t], qp["pos_embed.zp"][:t], nl_bits)
    mask = jnp.asarray(np.tril(np.ones((t, t), bool)))
    n_h, hd = cfg.n_heads, cfg.head_dim
    centered = cfg.arch == "opt"

    for i in range(cfg.n_layers):
        g = lambda s: qp[f"layers.{i}.{s}"]
        # ---- attention ----
        h, hm, hk, hzp = intops.di_norm(x, xzp, a_bits, centered)

        def lin(ln, hh=h, hhm=hm, hhk=hk, hhzp=hzp, bits=a_bits, li=i):
            pre = f"layers.{li}.{ln}"
            bq = qp.get(pre + ".bq") if cfg.arch == "opt" else None
            return intops.di_linear(hh, hhm, hhk, hhzp, qp[pre + ".wq"],
                                    qp[pre + ".mw"], qp[pre + ".kw"],
                                    bq, bits)

        qv, qm, qk, qzp = lin("attn.wq")
        kv, km, kk, kzp = lin("attn.wk")
        vv, vm_, vk_, vzp = lin("attn.wv")
        if cfg.arch == "llama":
            cos = qp["rope.cos"][:t]
            sin = qp["rope.sin"][:t]
            qc = intops.di_rope(qv.reshape(t, n_h, hd), qzp, cos, sin)
            kc = intops.di_rope(kv.reshape(t, n_h, hd), kzp, cos, sin)
        else:
            qc = (qv.reshape(t, n_h, hd) - qzp[:, None, None]).astype(I32)
            kc = (kv.reshape(t, n_h, hd) - kzp[:, None, None]).astype(I32)
        vc3 = vv.reshape(t, n_h, hd)
        # K, V to one shared scale per head (DESIGN §5, requant_per_head)
        kch, k_m, k_k, _ = intops.requant_per_head(
            kc, km, kk, None, a_bits)
        vch, v_m, v_k, _ = intops.requant_per_head(
            vc3, vm_, vk_, vzp, a_bits)
        qch = jnp.transpose(qc, (1, 0, 2)).astype(I64)  # (H, T, hd)
        p = jnp.einsum("htd,hsd->hts", qch, kch)  # i64 scores
        probs = intops.di_clipped_softmax(
            p.reshape(n_h * t, t),
            jnp.tile(qm, n_h), jnp.tile(qk, n_h),
            jnp.repeat(k_m, t), jnp.repeat(k_k, t),
            scheme.softmax_bits, mask=jnp.tile(mask, (n_h, 1)),
            clip=scheme.clip).reshape(n_h, t, t)
        o3 = jnp.einsum("hts,hsd->htd", probs.astype(I64), vch)
        att, am_, ak_, azp = _heads_merge_requant(
            o3, v_m, v_k, scheme.softmax_bits, a_bits)
        o, om, ok, ozp = intops.di_linear(
            att, am_, ak_, azp, g("attn.wo.wq"), g("attn.wo.mw"),
            g("attn.wo.kw"),
            g("attn.wo.bq") if cfg.arch == "opt" else None, a_bits)
        x, xm, xk, xzp = intops.di_add(x, xm, xk, xzp, o, om, ok, ozp,
                                       nl_bits)
        # ---- mlp ----
        h, hm, hk, hzp = intops.di_norm(x, xzp, a_bits, centered)
        if cfg.arch == "llama":
            gv, gm_, gk_, gzp = lin("mlp.wg", h, hm, hk, hzp, nl_bits)
            uv, um_, uk_, uzp = lin("mlp.wu", h, hm, hk, hzp, nl_bits)
            sw, sm, sk, szp = intops.di_swiglu(
                gv, gm_, gk_, gzp, uv, um_, uk_, uzp,
                g("alpha_m"), g("alpha_k"), scheme.sig_bits, a_bits)
            y, ym, yk, yzp = intops.di_linear(
                sw, sm, sk, szp, g("mlp.wd.wq"), g("mlp.wd.mw"),
                g("mlp.wd.kw"), None, a_bits)
        else:
            av, am2, ak2, azp2 = lin("mlp.w1", h, hm, hk, hzp)
            av = intops.di_relu(av, azp2)
            y, ym, yk, yzp = intops.di_linear(
                av, am2, ak2, azp2, g("mlp.w2.wq"), g("mlp.w2.mw"),
                g("mlp.w2.kw"), g("mlp.w2.bq"), a_bits)
        x, xm, xk, xzp = intops.di_add(x, xm, xk, xzp, y, ym, yk, yzp,
                                       nl_bits)

    h, hm, hk, hzp = intops.di_norm(x, xzp, nl_bits, centered)
    p, m_in, k_in = intops.di_linear_raw(
        h, hm, hk, hzp, qp["lm_head.wq"], qp["lm_head.mw"],
        qp["lm_head.kw"], qp.get("lm_head.bq"))
    # model boundary: dequantize logits (the only float op in the graph)
    s = m_in.astype(jnp.float64) / jnp.exp2(k_in.astype(jnp.float64))
    return (p.astype(jnp.float64) * s[:, None]).astype(F32)
